"""Input pipeline: double-buffered device prefetch — the TPU analogue of the
paper's BRAM0/BRAM1 ping-pong (§3): while the accelerator consumes batch i,
batch i+1 is generated and transferred. Plus sharded global-batch placement
for multi-host meshes.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import jax

__all__ = ["prefetch", "shard_batch", "HostLoader"]


def prefetch(it: Iterator[Any], size: int = 2) -> Iterator[Any]:
    """Background-thread prefetch queue of depth ``size`` (2 = ping-pong)."""
    q: "queue.Queue" = queue.Queue(maxsize=size)
    sentinel = object()
    err: list = []

    def worker():
        try:
            for x in it:
                q.put(x)
        except Exception as e:        # propagate into the consumer
            err.append(e)
        finally:
            q.put(sentinel)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        x = q.get()
        if x is sentinel:
            if err:
                raise err[0]
            return
        yield x


def shard_batch(batch, sharding) -> Any:
    """Place a host batch onto the mesh with the given NamedSharding tree."""
    if sharding is None:
        return batch
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), batch, sharding)


class HostLoader:
    """Deterministic step-indexed loader: batch = fn(seed, step).

    Restart/elasticity: nothing to checkpoint except the step counter — any
    host can regenerate any shard (see data.synthetic docstring).
    """

    def __init__(self, batch_fn: Callable[[int, int], Any], *, seed: int = 0,
                 start_step: int = 0, sharding=None, prefetch_depth: int = 2):
        self.batch_fn = batch_fn
        self.seed = seed
        self.step = start_step
        self.sharding = sharding
        self.prefetch_depth = prefetch_depth

    def __iter__(self):
        def gen():
            step = self.step
            while True:
                b = self.batch_fn(self.seed, step)
                yield shard_batch(b, self.sharding)
                step += 1

        return prefetch(gen(), self.prefetch_depth)
