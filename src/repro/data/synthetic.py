"""Deterministic synthetic datasets.

LM stream: Zipf-ish token sequences with local n-gram structure so the loss
has real signal (a learnable bigram process, not uniform noise). Digit /
phoneme: class-prototype + noise classification sets with the paper's exact
dims (784->10, 429->61), standing in for MNIST/TIMIT which are not
redistributable inside this container (DESIGN §10) — the *quantization gap*
(float vs W3) is the reproduced quantity.

All generators are pure functions of (seed, step/index) — any shard of any
batch can be regenerated anywhere, which is what makes the input pipeline
elastically restartable (no data-state in checkpoints beyond the step).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["lm_batch", "ClassificationTask", "digit_task", "phoneme_task"]


# --- LM stream -----------------------------------------------------------------

@partial(jax.jit, static_argnames=("batch", "seq", "vocab"))
def lm_batch(seed: jnp.ndarray, step: jnp.ndarray, *, batch: int, seq: int,
             vocab: int) -> Dict[str, jnp.ndarray]:
    """Markov-ish tokens: x[t+1] = (a*x[t] + noise) % vocab — learnable."""
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    key = jax.random.fold_in(key, step)
    k1, k2 = jax.random.split(key)
    x0 = jax.random.randint(k1, (batch, 1), 0, vocab)
    noise = jax.random.randint(k2, (batch, seq), 0, max(vocab // 16, 2))

    def body(x, n):
        nxt = (x * 31 + 17 + n) % vocab
        return nxt, nxt

    _, xs = jax.lax.scan(body, x0[:, 0], noise.T)
    toks = jnp.concatenate([x0, xs.T[:, :-1]], axis=1)
    labels = xs.T
    return {"tokens": toks, "labels": labels}


# --- classification (paper repro) -------------------------------------------------

class ClassificationTask:
    """Prototype-based synthetic classification with train/test splits."""

    def __init__(self, input_dim: int, num_classes: int, *, seed: int = 0,
                 noise: float = 0.5, sparsity: float = 0.2,
                 n_train: int = 10_000, n_test: int = 2_000):
        """MNIST-like statistics: sparse smooth nonnegative prototypes, inputs
        clipped to [0,1] (the paper's 8-bit gray pixels). Sigmoid nets + the
        Bernoulli RBM pretraining recipe behave as they do on MNIST."""
        rng = np.random.RandomState(seed)
        self.input_dim, self.num_classes = input_dim, num_classes
        base = rng.randn(num_classes, input_dim)
        kernel = np.exp(-0.5 * (np.arange(-8, 9) / 3.0) ** 2)
        smooth = np.stack([np.convolve(b, kernel, mode="same") for b in base])
        thresh = np.quantile(smooth, 1 - sparsity, axis=1, keepdims=True)
        self.prototypes = (smooth >= thresh).astype(np.float32)  # sparse blobs
        self.noise = noise
        self.train = self._draw(rng, n_train)
        self.test = self._draw(rng, n_test)

    def _draw(self, rng, n) -> Tuple[np.ndarray, np.ndarray]:
        y = rng.randint(0, self.num_classes, size=n)
        x = self.prototypes[y] + rng.randn(n, self.input_dim) * self.noise
        return np.clip(x, 0.0, 1.0).astype(np.float32), y.astype(np.int32)

    def batches(self, split: str, batch: int, *, seed: int = 0, epochs: int = 1):
        x, y = self.train if split == "train" else self.test
        rng = np.random.RandomState(seed)
        for _ in range(epochs):
            idx = rng.permutation(len(x))
            for i in range(0, len(x) - batch + 1, batch):
                j = idx[i:i + batch]
                yield jnp.asarray(x[j]), jnp.asarray(y[j])


def digit_task(**kw) -> ClassificationTask:
    """Paper's digit net input space: 784 -> 10 (28x28 8-bit gray analogue).

    noise tuned so a float MLP lands near the paper's ~1% MCR regime
    (nearest-prototype ~2-3%; the MLP beats it)."""
    kw.setdefault("noise", 2.5)
    return ClassificationTask(784, 10, **kw)


def phoneme_task(**kw) -> ClassificationTask:
    """Paper's phoneme net input space: 429 -> 61 (11 frames of MFCC).

    noise tuned toward the paper's ~28% PER regime."""
    kw.setdefault("noise", 2.3)
    return ClassificationTask(429, 61, **kw)
