"""Modality frontend STUBS per assignment: ``[audio]``/``[vlm]`` archs get
precomputed frame/patch embeddings — the EnCodec encoder / InternViT tower is
out of scope; ``input_specs()`` supplies their outputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["frontend_embed_shape", "synthetic_frontend_embeds", "text_len"]


def frontend_embed_shape(cfg: ModelConfig, batch: int):
    """(B, F, d_model) precomputed embedding stand-in shape."""
    return (batch, cfg.frontend_tokens, cfg.d_model)


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Token positions left for text when the frontend prefix is included."""
    if cfg.frontend is None:
        return seq_len
    return max(seq_len - cfg.frontend_tokens, 1)


def synthetic_frontend_embeds(key, cfg: ModelConfig, batch: int,
                              dtype=jnp.bfloat16) -> jnp.ndarray:
    return jax.random.normal(key, frontend_embed_shape(cfg, batch), dtype) * 0.02
