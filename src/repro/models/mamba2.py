"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) backbone.

Train/prefill uses the chunked SSD algorithm: within a chunk the recurrence is
expanded into an attention-like masked matmul (quadratic in the chunk length
only); across chunks a ``lax.scan`` carries the (H, P, N) state — overall
O(L·Q) compute and O(L) memory, sub-quadratic in sequence length (this is why
the ssm family runs the ``long_500k`` shape). Decode is the pure recurrence:
one state update per token, no KV growth.

Quantization (DESIGN §Arch-applicability): in/out projections are role
'hidden' (W3 — >90% of params); SSM dynamics A_log/dt_bias/D/conv stay fp32
(role 'ssm'), the analogue of the paper's sensitive 8-bit output layer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant_dense
from repro.core.precision import QuantPolicy
from repro.distributed.context import constrain
from repro.models.layers import (embed_init, embed_lookup, logits_readout,
                                 rmsnorm, rmsnorm_init)

__all__ = ["init", "forward", "init_state", "decode_step", "verify_step",
           "rollback_cache", "spec_state_snapshot", "insert_prefill",
           "insert_prefill_many", "block_init", "block_apply", "block_decode",
           "DEFAULT_CHUNK"]

DEFAULT_CHUNK = 256


# --- parameter init ---------------------------------------------------------------

def block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    d, di, ns, g, h = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_ngroups, cfg.ssm_heads)
    conv_ch = di + 2 * g * ns
    in_dim = 2 * di + 2 * g * ns + h
    ks = jax.random.split(key, 7)
    p = {
        "norm": rmsnorm_init(d),
        "out_proj": quant_dense.init(ks[1], di, d, bias=False, dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "ssm_d": jnp.ones((h,), jnp.float32),
        "gate_norm": rmsnorm_init(di),
    }
    if cfg.ssm_split_proj:
        # shard-aligned component projections + per-component convs: the
        # fused in_proj's z|x|B|C|dt split points fall inside TP shards,
        # forcing GSPMD reshards every layer (§Perf H-split)
        p.update({
            "wz": quant_dense.init(ks[0], d, di, bias=False, dtype=dtype),
            "wx": quant_dense.init(ks[2], d, di, bias=False, dtype=dtype),
            "wbc": quant_dense.init(ks[3], d, 2 * g * ns, bias=False,
                                    dtype=dtype),
            "wdt": quant_dense.init(ks[4], d, h, bias=False, dtype=dtype),
            "conv_x_w": jax.random.normal(ks[5], (cfg.ssm_conv, di),
                                          dtype) * 0.1,
            "conv_x_b": jnp.zeros((di,), dtype),
            "conv_bc_w": jax.random.normal(ks[6], (cfg.ssm_conv, 2 * g * ns),
                                           dtype) * 0.1,
            "conv_bc_b": jnp.zeros((2 * g * ns,), dtype),
        })
    else:
        p.update({
            "in_proj": quant_dense.init(ks[0], d, in_dim, bias=False,
                                        dtype=dtype),
            "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv, conv_ch),
                                        dtype) * 0.1,
            "conv_b": jnp.zeros((conv_ch,), dtype),
        })
    return p


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    lk = jax.random.split(ks[0], cfg.num_layers)
    layers = jax.vmap(lambda k: block_init(k, cfg, dtype))(lk)
    params = {"embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
              "layers": layers, "final_norm": rmsnorm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["head"] = quant_dense.init(ks[2], cfg.d_model, cfg.vocab_size,
                                          bias=False, dtype=dtype)
    return params


# --- projections -------------------------------------------------------------------

def _dget(deltas, *names):
    node = deltas
    for n in names:
        if node is None:
            return None
        node = node.get(n)
    return node


def _split_proj(zxbcdt, cfg: ModelConfig):
    di, ns, g, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_heads
    z, x, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * g * ns], axis=-1)
    return z, x, bc, dt


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d. x (B,L,C), w (W,C). Returns (y, new_state).

    ``state``: (B, W-1, C) trailing context (decode carries it)."""
    wlen = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (wlen - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(wlen))
    new_state = xp[:, -(wlen - 1):] if wlen > 1 else None
    return jax.nn.silu(y + b), new_state


# --- chunked SSD core ---------------------------------------------------------------

def _ssd_chunked(x, b_mat, c_mat, dt, a_log, chunk: int, bf16: bool = False):
    """SSD over the full sequence.

    x  (B, L, H, P) head values;   b_mat/c_mat (B, L, G, N) shared per group;
    dt (B, L, H) positive step;    a_log (H,) => a = -exp(a_log).
    Returns y (B, L, H, P). fp32 internals; ``bf16`` keeps the big einsum
    operands (x, B, C, decay matrix) in bfloat16 — the decay recurrences /
    cumsum/exp stay fp32 (beyond-paper §Perf H-ssd-bf16).
    """
    bsz, l, h, p = x.shape
    g = b_mat.shape[2]
    n = b_mat.shape[3]
    rep = h // g
    q = min(chunk, l)
    nchunks = -(-l // q)
    pad = nchunks * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    a = -jnp.exp(a_log.astype(jnp.float32))                    # (H,) negative
    dta = dt.astype(jnp.float32) * a                           # (B, L', H) = log decay
    op_dtype = jnp.bfloat16 if bf16 else jnp.float32
    xw = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
          ).astype(op_dtype)                                   # dt-weighted input

    def rs(t, extra):  # (B, L', ...) -> (nchunks, B, q, ...)
        return t.reshape(bsz, nchunks, q, *extra).transpose(1, 0, 2, *range(3, 3 + len(extra)))

    xs = (rs(xw, (h, p)), rs(b_mat.astype(op_dtype), (g, n)),
          rs(c_mat.astype(op_dtype), (g, n)), rs(dta, (h,)))

    def body(state, xs_c):
        xc, bc, cc, dac = xs_c                                  # per-chunk slices
        lcum = jnp.cumsum(dac, axis=1)                          # (B,q,H) inclusive
        ltot = lcum[:, -1]                                      # (B,H)
        # broadcast B/C groups to heads
        bh = jnp.repeat(bc, rep, axis=2)                        # (B,q,H,N)
        ch = jnp.repeat(cc, rep, axis=2)
        # --- intra-chunk (attention-like) ---
        # att[i,j] = (C_i . B_j) * exp(lcum_i - lcum_j) for j <= i
        scores = jnp.einsum("bihn,bjhn->bhij", ch, bh,
                            preferred_element_type=jnp.float32)
        decay = lcum[:, :, None, :] - lcum[:, None, :, :]       # (B,i,j,H)
        causal = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), bool))
        # mask the EXPONENT: exp() of masked (future) entries overflows, and
        # where(mask, inf, 0) backprops inf*0 = NaN
        decay = jnp.where(causal[None, :, :, None], decay, -jnp.inf)
        w = jnp.exp(decay).astype(op_dtype)
        y_intra = jnp.einsum("bhij,bijh,bjhp->bihp", scores.astype(op_dtype),
                             w, xc, preferred_element_type=jnp.float32)
        # --- inter-chunk: contribution of carried state ---
        y_inter = jnp.einsum("bihn,bhpn->bihp", ch.astype(jnp.float32),
                             state) * jnp.exp(lcum)[..., None]
        # --- state update ---
        carry_w = jnp.exp(ltot[:, None, :] - lcum)              # (B,q,H)
        new_state = state * jnp.exp(ltot)[..., None, None] + jnp.einsum(
            "bjhn,bjh,bjhp->bhpn", bh.astype(jnp.float32), carry_w,
            xc.astype(jnp.float32))
        return new_state, y_intra + y_inter

    from repro.distributed.context import inner_unroll
    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    s_final, ys = jax.lax.scan(body, s0, xs,
                               unroll=True if inner_unroll() else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nchunks * q, h, p)
    return y[:, :l], s_final


def block_apply(lp, h_in: jnp.ndarray, cfg: ModelConfig, *, policy: QuantPolicy,
                deltas: Optional[Dict] = None, chunk: int = DEFAULT_CHUNK,
                return_state: bool = False,
                lengths: Optional[jnp.ndarray] = None,
                matmul_mode: str = "auto"):
    """Full Mamba2 block (pre-norm residual).

    With ``return_state`` returns (out, {"ssm", "conv"}) — the exact decode
    state after the sequence (prefill→decode continuation).

    ``lengths`` (B,) marks right-padded rows: dt is zeroed at padding
    positions, which makes the SSD recurrence an exact identity there
    (decay ``exp(0·a)=1``, input weight ``dt·x=0``) — so the carried SSM
    state equals the state after each row's last REAL token. The conv state
    is gathered from each row's true trailing window for the same reason.
    The causal conv itself needs no masking: position ``i < len`` only sees
    inputs ``<= i``, all real."""
    bsz, l, _ = h_in.shape
    hn = rmsnorm(lp["norm"], h_in, cfg.norm_eps)
    di = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    if cfg.ssm_split_proj:
        z = quant_dense.apply(lp["wz"], hn, policy=policy, role="hidden",
                              delta=_dget(deltas, "wz", "w"), mode=matmul_mode)
        x0 = quant_dense.apply(lp["wx"], hn, policy=policy, role="hidden",
                               delta=_dget(deltas, "wx", "w"),
                               mode=matmul_mode)
        bc0 = quant_dense.apply(lp["wbc"], hn, policy=policy, role="hidden",
                                delta=_dget(deltas, "wbc", "w"),
                                mode=matmul_mode)
        dt = quant_dense.apply(lp["wdt"], hn, policy=policy, role="hidden",
                               delta=_dget(deltas, "wdt", "w"),
                               mode=matmul_mode)
        xbc_pre = jnp.concatenate([x0, bc0], axis=-1)
        x, _ = _causal_conv(x0, lp["conv_x_w"], lp["conv_x_b"])
        bc, _ = _causal_conv(bc0, lp["conv_bc_w"], lp["conv_bc_b"])
        b_mat, c_mat = jnp.split(bc, [gn], axis=-1)
    else:
        zxbcdt = quant_dense.apply(lp["in_proj"], hn, policy=policy,
                                   role="hidden",
                                   delta=_dget(deltas, "in_proj", "w"),
                                   mode=matmul_mode)
        z, x, bc, dt = _split_proj(zxbcdt, cfg)
        xbc_pre = jnp.concatenate([x, bc], axis=-1)
        xbc, _ = _causal_conv(xbc_pre, lp["conv_w"], lp["conv_b"])
        x, b_mat, c_mat = jnp.split(xbc, [di, di + gn], axis=-1)
    x = x.reshape(bsz, l, cfg.ssm_heads, cfg.ssm_headdim)
    b_mat = b_mat.reshape(bsz, l, cfg.ssm_ngroups, cfg.ssm_state)
    c_mat = c_mat.reshape(bsz, l, cfg.ssm_ngroups, cfg.ssm_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    if lengths is not None:
        valid = jnp.arange(l)[None, :] < lengths[:, None]           # (B, L)
        dt = dt * valid[..., None]          # identity recurrence at padding
    y, s_final = _ssd_chunked(x, b_mat, c_mat, dt, lp["a_log"], chunk,
                              bf16=cfg.ssm_bf16)
    y = y + x.astype(jnp.float32) * lp["ssm_d"][:, None]        # D skip
    y = y.reshape(bsz, l, di).astype(h_in.dtype)
    y = rmsnorm(lp["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = quant_dense.apply(lp["out_proj"], y, policy=policy, role="hidden",
                            delta=_dget(deltas, "out_proj", "w"),
                            mode=matmul_mode)
    out = constrain(h_in + out, "act")
    if return_state:
        wlen = cfg.ssm_conv
        if lengths is not None:
            # per-row trailing window [len-(W-1), len): positions < 0 are
            # the initial zero conv state (short prompts)
            idx = lengths[:, None] - (wlen - 1) + jnp.arange(wlen - 1)[None]
            tail = jnp.take_along_axis(xbc_pre.astype(jnp.float32),
                                       jnp.maximum(idx, 0)[:, :, None], axis=1)
            tail = jnp.where((idx >= 0)[:, :, None], tail, 0.0)
        else:
            pad = max(wlen - 1 - l, 0)
            tail = xbc_pre[:, -(wlen - 1):].astype(jnp.float32)
            if pad:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"ssm": s_final, "conv": tail}
    return out


# --- decode (pure recurrence) ---------------------------------------------------------

def block_state(cfg: ModelConfig, batch: int):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
    }


def block_decode(lp, h_in: jnp.ndarray, state: Dict, cfg: ModelConfig, *,
                 policy: QuantPolicy, deltas: Optional[Dict] = None,
                 matmul_mode: str = "auto"):
    """One-token step. h_in (B,1,d). Returns (h_out, new_state)."""
    bsz = h_in.shape[0]
    hn = rmsnorm(lp["norm"], h_in, cfg.norm_eps)
    di = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    if cfg.ssm_split_proj:
        z = quant_dense.apply(lp["wz"], hn, policy=policy, role="hidden",
                              delta=_dget(deltas, "wz", "w"), mode=matmul_mode)
        x0 = quant_dense.apply(lp["wx"], hn, policy=policy, role="hidden",
                               delta=_dget(deltas, "wx", "w"),
                               mode=matmul_mode)
        bc0 = quant_dense.apply(lp["wbc"], hn, policy=policy, role="hidden",
                                delta=_dget(deltas, "wbc", "w"),
                                mode=matmul_mode)
        dt = quant_dense.apply(lp["wdt"], hn, policy=policy, role="hidden",
                               delta=_dget(deltas, "wdt", "w"),
                               mode=matmul_mode)
        cs_x, cs_bc = jnp.split(state["conv"], [di], axis=-1)
        x, cx = _causal_conv(x0, lp["conv_x_w"], lp["conv_x_b"], cs_x)
        bc, cbc = _causal_conv(bc0, lp["conv_bc_w"], lp["conv_bc_b"], cs_bc)
        conv_state = jnp.concatenate([cx, cbc], axis=-1)
        b_mat, c_mat = jnp.split(bc, [gn], axis=-1)
    else:
        zxbcdt = quant_dense.apply(lp["in_proj"], hn, policy=policy,
                                   role="hidden",
                                   delta=_dget(deltas, "in_proj", "w"),
                                   mode=matmul_mode)
        z, x, bc, dt = _split_proj(zxbcdt, cfg)
        xbc, conv_state = _causal_conv(jnp.concatenate([x, bc], axis=-1),
                                       lp["conv_w"], lp["conv_b"],
                                       state["conv"])
        x, b_mat, c_mat = jnp.split(xbc, [di, di + gn], axis=-1)
    h, p, n, g = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_ngroups
    x = x.reshape(bsz, h, p).astype(jnp.float32)
    rep = h // g
    b1 = jnp.repeat(b_mat.reshape(bsz, g, n), rep, axis=1).astype(jnp.float32)
    c1 = jnp.repeat(c_mat.reshape(bsz, g, n), rep, axis=1).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt.reshape(bsz, h).astype(jnp.float32) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * a)                                    # (B,H)
    # S <- decay*S + dt * B x^T ;  y = C . S + D*x
    s_new = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt1, b1, x)
    y = jnp.einsum("bhn,bhpn->bhp", c1, s_new) + lp["ssm_d"][:, None] * x
    y = y.reshape(bsz, 1, di).astype(h_in.dtype)
    y = rmsnorm(lp["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = quant_dense.apply(lp["out_proj"], y, policy=policy, role="hidden",
                            delta=_dget(deltas, "out_proj", "w"),
                            mode=matmul_mode)
    # keep the carried state's canonical fp32 dtype (block_state): the conv
    # tail comes back in the activation dtype, and a bf16 drift would make
    # every decode re-trace — and break scan-carried decode chains
    # (speculative drafting) outright
    conv_state = conv_state.astype(state["conv"].dtype)
    return h_in + out, {"ssm": s_new, "conv": conv_state}


# --- whole-model wrappers ---------------------------------------------------------------

def forward(params, batch, cfg: ModelConfig, *, policy: QuantPolicy,
            deltas: Optional[Dict] = None, dtype=jnp.bfloat16,
            remat: str = "layer", attn_chunk: int = 0,
            chunk: int = DEFAULT_CHUNK,
            matmul_mode: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = embed_lookup(params["embed"], batch["tokens"], policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    h = constrain(h, "act")

    def body(hh, xs):
        lp, ld = xs
        return block_apply(lp, hh, cfg, policy=policy, deltas=ld, chunk=chunk,
                           matmul_mode=matmul_mode), None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    ld = deltas.get("layers") if deltas else None
    h, _ = jax.lax.scan(body, h, (params["layers"], ld))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return (_logits(params, h, cfg, policy, deltas, matmul_mode),
            jnp.zeros((), jnp.float32))


def _logits(params, h, cfg, policy, deltas, mm: str = "auto"):
    return logits_readout(params, h, cfg, policy=policy,
                          embed_delta=_dget(deltas, "embed", "w"),
                          head_delta=_dget(deltas, "head", "w"),
                          matmul_mode=mm)


def init_state(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=jnp.bfloat16):
    """Decode state for all layers (stacked). max_len unused (O(1) state)."""
    one = block_state(cfg, batch)
    return {"layers": jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), one),
        "len": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg: ModelConfig, *, policy: QuantPolicy,
            deltas=None, dtype=jnp.bfloat16, attn_chunk: int = 0,
            max_len: Optional[int] = None, chunk: int = DEFAULT_CHUNK,
            lengths: Optional[jnp.ndarray] = None,
            matmul_mode: str = "auto"):
    """Prompt pass returning final logits + exact decode-ready state.

    ``lengths`` (B,) enables right-padded multi-request prefill: the SSD
    recurrence is masked so each row's state stops at its true length,
    logits come from each row's last real token, and ``len`` is per-row."""
    h = embed_lookup(params["embed"], batch["tokens"], policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    bsz, l = batch["tokens"].shape
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)

    def body(hh, xs):
        lp, ld = xs
        out, st = block_apply(lp, hh, cfg, policy=policy, deltas=ld,
                              chunk=chunk, return_state=True, lengths=lengths,
                              matmul_mode=matmul_mode)
        return out, st

    ld = deltas.get("layers") if deltas else None
    h, states = jax.lax.scan(body, h, (params["layers"], ld))
    if lengths is not None:
        h = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)
    else:
        h = h[:, -1:]
    hln = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, hln, cfg, policy, deltas, matmul_mode)
    clen = jnp.asarray(l, jnp.int32) if lengths is None else lengths
    return logits, {"layers": states, "len": clen}


def decode_step(params, state, tokens: jnp.ndarray, cfg: ModelConfig, *,
                policy: QuantPolicy, deltas=None, dtype=jnp.bfloat16,
                matmul_mode: str = "auto"):
    h = embed_lookup(params["embed"], tokens, policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)

    def body(hh, xs):
        lp, ld, st = xs
        hh, st2 = block_decode(lp, hh, st, cfg, policy=policy, deltas=ld,
                               matmul_mode=matmul_mode)
        return hh, st2

    ld = deltas.get("layers") if deltas else None
    h, new_layers = jax.lax.scan(body, h, (params["layers"], ld, state["layers"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, h, cfg, policy, deltas, matmul_mode)
    return logits, {"layers": new_layers, "len": state["len"] + 1}


_NO_SPEC = ("family 'ssm' does not support speculative decoding: the SSD "
            "recurrence folds every token into one fixed-size state, so a "
            "rejected draft suffix cannot be rewound (no KV length to "
            "rewind, and snapshotting every per-layer state per draft "
            "token would defeat the O(1)-state point of the family)")


def verify_step(params, state, tokens, cfg, **kw):
    """Speculative verify is structurally unavailable for the pure-SSM
    family — reject loudly instead of silently corrupting the state."""
    raise ValueError(_NO_SPEC)


def spec_state_snapshot(state):
    raise ValueError(_NO_SPEC)


def rollback_cache(state, slots, new_lens, trajectory=None):
    raise ValueError(_NO_SPEC)


def free_slots(state, slots):
    """Zero rows ``slots`` (N,) of a slot-major state (conv + SSM states)
    and reset their ``len`` — the preemption/deadline/quarantine release
    primitive. The SSD state is a running fold, so zeroing IS the fresh
    state; out-of-range entries are dropped (padding convention)."""
    layers = jax.tree_util.tree_map(
        lambda x: x.at[:, slots].set(0, mode="drop"), state["layers"])
    ln = state["len"].at[slots].set(0, mode="drop")
    return {"layers": layers, "len": ln}


def insert_prefill(state, slot, src):
    """Copy a single-request prefill state (batch=1) into row ``slot`` of a
    slot-major shared state whose ``len`` is per-slot (slots,). ``slot`` may
    be traced. Every layer leaf is (L, B, ...): batch axis 1."""
    layers = jax.tree_util.tree_map(
        lambda dst, s: jax.lax.dynamic_update_slice_in_dim(
            dst, s.astype(dst.dtype), slot, 1),
        state["layers"], src["layers"])
    ln = jax.lax.dynamic_update_slice(
        state["len"], jnp.reshape(src["len"], (1,)).astype(state["len"].dtype),
        (slot,))
    return {"layers": layers, "len": ln}


def insert_prefill_many(state, slot_map, src):
    """Scatter an N-row batched prefill state into rows ``slot_map`` (N,) of
    a slot-major shared state (per-slot ``len``). Entries with
    ``slot_map[i] >= slots`` are dropped (padding rows)."""
    layers = jax.tree_util.tree_map(
        lambda dst, s: dst.at[:, slot_map].set(s.astype(dst.dtype),
                                               mode="drop"),
        state["layers"], src["layers"])
    ln = state["len"].at[slot_map].set(
        jnp.asarray(src["len"]).astype(state["len"].dtype), mode="drop")
    return {"layers": layers, "len": ln}
