"""Shared model layers: norms, RoPE, MLPs, embeddings — all quantizable.

Every weight matmul routes through ``repro.core.quant_dense.apply`` so the
paper's W3A8 policy applies uniformly across the zoo. Norms/biases stay fp32
per the paper (§2.1 keeps only weight matrices fixed-point).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import qat, quant_dense
from repro.core.precision import QuantPolicy

__all__ = ["rmsnorm_init", "rmsnorm", "rope_freqs", "apply_rope",
           "mlp_init", "mlp_apply", "embed_init", "embed_lookup",
           "embed_logits", "logits_readout", "act_fn"]


# --- norms --------------------------------------------------------------------

def rmsnorm_init(dim: int) -> Dict[str, Any]:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params: Dict[str, Any], x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def head_rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """qk-norm: RMSNorm over the head_dim of (..., H, D) tensors."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# --- rotary embeddings ----------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim//2,), fp32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, inv_freq: jnp.ndarray) -> jnp.ndarray:
    """Rotate (..., S, H, D). ``positions``: (..., S) int32."""
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- activations ----------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "sigmoid": jax.nn.sigmoid, "relu": jax.nn.relu}[name]


# --- MLP ------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str = "silu",
             dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    p = {"up": quant_dense.init(ks[0], d_model, d_ff, bias=False, dtype=dtype),
         "down": quant_dense.init(ks[1], d_ff, d_model, bias=False, dtype=dtype)}
    if act == "silu":  # SwiGLU
        p["gate"] = quant_dense.init(ks[2], d_model, d_ff, bias=False, dtype=dtype)
    return p


def mlp_apply(params: Dict[str, Any], x: jnp.ndarray, *, act: str,
              policy: QuantPolicy, deltas: Optional[Dict] = None,
              matmul_mode: str = "auto") -> jnp.ndarray:
    d = deltas or {}
    fn = act_fn(act)
    up = quant_dense.apply(params["up"], x, policy=policy, role="hidden",
                           delta=(d.get("up") or {}).get("w"),
                           mode=matmul_mode)
    if "gate" in params:
        gate = quant_dense.apply(params["gate"], x, policy=policy, role="hidden",
                                 delta=(d.get("gate") or {}).get("w"),
                                 mode=matmul_mode)
        h = fn(gate) * up
    else:
        h = fn(up)
    if policy.act_bits:
        h = qat.fake_quant_act(h, policy.act_bits)
    return quant_dense.apply(params["down"], h, policy=policy, role="hidden",
                             delta=(d.get("down") or {}).get("w"),
                             mode=matmul_mode)


# --- embeddings -----------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> Dict[str, Any]:
    w = jax.random.normal(key, (vocab, d_model), dtype) * 0.02
    return {"w": w}


def embed_lookup(params: Dict[str, Any], tokens: jnp.ndarray, *,
                 policy: QuantPolicy, delta=None, dtype=jnp.bfloat16) -> jnp.ndarray:
    if "q" in params:          # serve form: gather int8 rows, then dequantize
        rows = params["q"][tokens].astype(jnp.float32) * params["delta"]
        return rows.astype(dtype)
    w = quant_dense.effective_weight(params, policy, "embed", delta)
    return w.astype(dtype)[tokens]


def embed_logits(params: Dict[str, Any], h: jnp.ndarray, *,
                 policy: QuantPolicy, delta=None,
                 matmul_mode: str = "auto") -> jnp.ndarray:
    """Tied-embedding readout: h @ E^T (role 'output', 8-bit per paper).

    Serve-form tables go through ``quant_dense.tied_logits`` — delta folds
    into the activations, the int8 table is never dequantized in-graph."""
    if "q" in params:
        return quant_dense.tied_logits(params, h, mode=matmul_mode)
    w = quant_dense.effective_weight(params, policy, "output", delta)
    return h @ w.astype(h.dtype).T


def logits_readout(params: Dict[str, Any], h: jnp.ndarray, cfg, *,
                   policy: QuantPolicy, embed_delta=None, head_delta=None,
                   matmul_mode: str = "auto") -> jnp.ndarray:
    """Final LM readout, shared by every family: tied-embedding or separate
    head per ``cfg.tie_embeddings``, fp32 logits under the sharding
    constraint."""
    from repro.distributed.context import constrain

    if cfg.tie_embeddings:
        out = embed_logits(params["embed"], h, policy=policy,
                           delta=embed_delta, matmul_mode=matmul_mode)
    else:
        out = quant_dense.apply(params["head"], h, policy=policy,
                                role="output", delta=head_delta,
                                mode=matmul_mode)
    return constrain(out.astype(jnp.float32), "logits")
