"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block applied
every ``attn_every`` layers (arXiv:2411.15242).

Layout: ``num_layers = n_groups * attn_every + n_tail``. Each group = a scan
over ``attn_every`` mamba blocks followed by the shared transformer block
(same weights every application — closed over, not scanned). Decode keeps one
KV cache per application (n_groups caches) + per-layer mamba states.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant_dense
from repro.core.precision import QuantPolicy
from repro.distributed.context import constrain
from repro.models import mamba2, transformer
from repro.models.layers import (embed_init, embed_lookup, logits_readout,
                                 rmsnorm, rmsnorm_init)

__all__ = ["init", "forward", "init_cache", "prefill", "decode_step",
           "verify_step", "rollback_cache", "spec_state_snapshot",
           "insert_prefill", "insert_prefill_many"]


def _counts(cfg: ModelConfig) -> Tuple[int, int]:
    n_groups = cfg.num_layers // cfg.attn_every
    n_tail = cfg.num_layers % cfg.attn_every
    return n_groups, n_tail


def _dget(deltas, *names):
    node = deltas
    for n in names:
        if node is None:
            return None
        node = node.get(n)
    return node


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    n_groups, n_tail = _counts(cfg)
    ks = jax.random.split(key, 5)
    gkeys = jax.random.split(ks[0], n_groups * cfg.attn_every).reshape(
        n_groups, cfg.attn_every, 2)
    groups = jax.vmap(jax.vmap(lambda k: mamba2.block_init(k, cfg, dtype)))(gkeys)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "groups": groups,
        "shared": transformer._layer_init(ks[2], cfg, dtype),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if n_tail:
        tkeys = jax.random.split(ks[3], n_tail)
        params["tail"] = jax.vmap(lambda k: mamba2.block_init(k, cfg, dtype))(tkeys)
    if not cfg.tie_embeddings:
        params["head"] = quant_dense.init(ks[4], cfg.d_model, cfg.vocab_size,
                                          bias=False, dtype=dtype)
    return params


def _mamba_scan(stack, dstack, h, cfg, policy, chunk, remat: str,
                return_state: bool = False, lengths=None, mm: str = "auto"):
    from repro.distributed.context import inner_unroll

    def body(hh, xs):
        lp, ld = xs
        if return_state:
            out, st = mamba2.block_apply(lp, hh, cfg, policy=policy, deltas=ld,
                                         chunk=chunk, return_state=True,
                                         lengths=lengths, matmul_mode=mm)
            return out, st
        return mamba2.block_apply(lp, hh, cfg, policy=policy, deltas=ld,
                                  chunk=chunk, lengths=lengths,
                                  matmul_mode=mm), None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    # cost-exact mode unrolls: this is the INNER loop of the hybrid group
    # scan — the L0/G1/A1 decomposition needs its body counted A times
    return jax.lax.scan(body, h, (stack, dstack),
                        unroll=True if inner_unroll() else 1)


def forward(params, batch, cfg: ModelConfig, *, policy: QuantPolicy,
            deltas: Optional[Dict] = None, dtype=jnp.bfloat16,
            remat: str = "layer", attn_chunk: int = 1024,
            chunk: int = mamba2.DEFAULT_CHUNK,
            matmul_mode: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    n_groups, n_tail = _counts(cfg)
    h = embed_lookup(params["embed"], batch["tokens"], policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    h = constrain(h, "act")
    s = h.shape[1]
    positions = jnp.arange(s)[None, :]
    inv_freq = transformer.rope_freqs(cfg.head_dim, cfg.rope_theta)
    shared, sdelta = params["shared"], _dget(deltas, "shared")

    def group_body(hh, xs):
        gp, gd = xs
        hh, _ = _mamba_scan(gp, gd, hh, cfg, policy, chunk, remat,
                            mm=matmul_mode)
        hh, _, _ = transformer._layer_forward(shared, sdelta, hh, cfg, policy,
                                              positions, inv_freq, attn_chunk,
                                              matmul_mode)
        return hh, None

    gd = _dget(deltas, "groups")
    h, _ = jax.lax.scan(group_body, h, (params["groups"], gd))
    if n_tail:
        h, _ = _mamba_scan(params["tail"], _dget(deltas, "tail"), h, cfg,
                           policy, chunk, remat, mm=matmul_mode)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return (_logits(params, h, cfg, policy, deltas, matmul_mode),
            jnp.zeros((), jnp.float32))


def _logits(params, h, cfg, policy, deltas, mm: str = "auto"):
    return logits_readout(params, h, cfg, policy=policy,
                          embed_delta=_dget(deltas, "embed", "w"),
                          head_delta=_dget(deltas, "head", "w"),
                          matmul_mode=mm)


# --- serving -----------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               quantized: bool = False):
    """Decode state: per-layer mamba states + one KV cache per shared-block
    application. ``quantized``: int8 KV entries + per-(group,batch,position)
    fp32 scales — the transformer family's §Perf H-kv8 cache, extended to
    the hybrid attention applications (half the KV bytes per slot)."""
    n_groups, n_tail = _counts(cfg)
    one = mamba2.block_state(cfg, batch)
    kv_shape = (n_groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    if quantized:
        kv = {"k": jnp.zeros(kv_shape, jnp.int8),
              "v": jnp.zeros(kv_shape, jnp.int8),
              "k_scale": jnp.zeros((n_groups, batch, max_len), jnp.float32),
              "v_scale": jnp.zeros((n_groups, batch, max_len), jnp.float32)}
    else:
        kv = {"k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype)}
    state = {
        "groups": jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                x, (n_groups, cfg.attn_every) + x.shape), one),
        "kv": kv,
        "len": jnp.zeros((), jnp.int32),
    }
    if n_tail:
        state["tail"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_tail,) + x.shape), one)
    return state


def prefill(params, batch, cfg: ModelConfig, *, policy: QuantPolicy,
            deltas=None, dtype=jnp.bfloat16, attn_chunk: int = 1024,
            max_len: Optional[int] = None, chunk: int = mamba2.DEFAULT_CHUNK,
            quantize_cache: bool = False,
            lengths: Optional[jnp.ndarray] = None,
            matmul_mode: str = "auto", attn_mode: str = "auto"):
    """``lengths`` (B,) enables right-padded multi-request prefill: mamba
    blocks mask the SSD recurrence / gather the true conv tail (see
    mamba2.block_apply), attention is causal so real positions never see the
    padding, and the junk K/V written at padded slots is masked out by decode
    (per-row ``len``) until overwritten. ``quantize_cache`` stores the KV
    cache as int8 + per-token scales (see :func:`init_cache`). ``attn_mode``
    dispatches the shared-block prompt attention between the blocked Pallas
    kernel and the chunked reference (see
    :func:`repro.models.attention.prefill_attention`)."""
    from repro.models.attention import resolve_attn_mode
    attn_mode = resolve_attn_mode(attn_mode)
    n_groups, n_tail = _counts(cfg)
    bsz, s = batch["tokens"].shape
    max_len = max_len or s
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        if s > max_len:
            raise ValueError(f"padded prefill length {s} exceeds max_len "
                             f"{max_len}")
    h = embed_lookup(params["embed"], batch["tokens"], policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    positions = jnp.arange(s)[None, :]
    inv_freq = transformer.rope_freqs(cfg.head_dim, cfg.rope_theta)
    shared, sdelta = params["shared"], _dget(deltas, "shared")

    def group_body(hh, xs):
        gp, gd = xs
        hh, mstates = _mamba_scan(gp, gd, hh, cfg, policy, chunk, "none",
                                  return_state=True, lengths=lengths,
                                  mm=matmul_mode)
        hh, _, (k, v) = transformer._layer_forward(
            shared, sdelta, hh, cfg, policy, positions, inv_freq, attn_chunk,
            matmul_mode, attn_mode, lengths)
        return hh, (mstates, k, v)

    gd = _dget(deltas, "groups")
    h, (gstates, ks, vs) = jax.lax.scan(group_body, h, (params["groups"], gd))
    state = init_cache(cfg, bsz, max_len, dtype, quantized=quantize_cache)
    state["groups"] = gstates
    pad = max_len - s
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if quantize_cache:
        qk, sk = jax.vmap(transformer._quantize_kv)(ks)   # over group dim
        qv, sv = jax.vmap(transformer._quantize_kv)(vs)
        state["kv"] = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    else:
        state["kv"]["k"] = ks.astype(dtype)
        state["kv"]["v"] = vs.astype(dtype)
    if n_tail:
        h, tstates = _mamba_scan(params["tail"], _dget(deltas, "tail"), h, cfg,
                                 policy, chunk, "none", return_state=True,
                                 lengths=lengths, mm=matmul_mode)
        state["tail"] = tstates
    if lengths is not None:
        h = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)
        state["len"] = lengths
    else:
        h = h[:, -1:]
        state["len"] = jnp.asarray(s, jnp.int32)
    hln = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _logits(params, hln, cfg, policy, deltas, matmul_mode), state


def decode_step(params, state, tokens: jnp.ndarray, cfg: ModelConfig, *,
                policy: QuantPolicy, deltas=None, dtype=jnp.bfloat16,
                matmul_mode: str = "auto", attn_mode: str = "auto"):
    """One token for the whole batch. ``state["len"]`` may be scalar (uniform
    batch) or (B,) per-row lengths (slot-major continuous batching).

    ``attn_mode`` picks the decode-attention implementation (fused Pallas
    kernel vs einsum reference — see
    :func:`repro.models.attention.decode_attention`); an int8 KV state
    (``k_scale`` present, from ``prefill(quantize_cache=True)``) is read
    directly with its per-token scales either way."""
    n_groups, n_tail = _counts(cfg)
    b = tokens.shape[0]
    pos = jnp.broadcast_to(state["len"], (b,)).astype(jnp.int32)   # (B,)
    quantized = "k_scale" in state["kv"]
    h = embed_lookup(params["embed"], tokens, policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    inv_freq = transformer.rope_freqs(cfg.head_dim, cfg.rope_theta)
    positions = pos[:, None]                                       # (B, 1)
    rows = jnp.arange(b)
    shared, sdelta = params["shared"], _dget(deltas, "shared")

    def mamba_body(hh, xs):
        lp, ld, st = xs
        hh, st2 = mamba2.block_decode(lp, hh, st, cfg, policy=policy,
                                      deltas=ld, matmul_mode=matmul_mode)
        return hh, st2

    def group_body(hh, xs):
        if quantized:
            gp, gd, gst, kc, vc, ks_, vs_ = xs
        else:
            gp, gd, gst, kc, vc = xs
            ks_ = vs_ = None
        hh, gst2 = jax.lax.scan(mamba_body, hh, (gp, gd, gst))
        hn = rmsnorm(shared["ln1"], hh, cfg.norm_eps)
        q, k, v = transformer._qkv(shared, hn, cfg, policy, sdelta, positions,
                                   inv_freq, matmul_mode)
        if quantized:
            kq, ksc = transformer._quantize_kv(k)
            vq, vsc = transformer._quantize_kv(v)
            kc = kc.at[rows, pos].set(kq[:, 0])
            vc = vc.at[rows, pos].set(vq[:, 0])
            ks_ = ks_.at[rows, pos].set(ksc[:, 0])
            vs_ = vs_.at[rows, pos].set(vsc[:, 0])
        else:
            kc = kc.at[rows, pos].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[rows, pos].set(v[:, 0].astype(vc.dtype))
        from repro.models.attention import decode_attention
        o = decode_attention(q, kc, vc, pos + 1, k_scale=ks_, v_scale=vs_,
                             mode=attn_mode)
        hh = hh + transformer._attn_out(shared, o, cfg, policy, sdelta, b, 1,
                                        matmul_mode)
        hn = rmsnorm(shared["ln2"], hh, cfg.norm_eps)
        f, _ = transformer._ffn(shared, hn, cfg, policy, sdelta, matmul_mode)
        out_kv = (gst2, kc, vc, ks_, vs_) if quantized else (gst2, kc, vc)
        return hh + f, out_kv

    gd = _dget(deltas, "groups")
    kv = state["kv"]
    if quantized:
        h, (gstates, ks, vs, ksc, vsc) = jax.lax.scan(
            group_body, h, (params["groups"], gd, state["groups"],
                            kv["k"], kv["v"], kv["k_scale"], kv["v_scale"]))
        new_kv = {"k": ks, "v": vs, "k_scale": ksc, "v_scale": vsc}
    else:
        h, (gstates, ks, vs) = jax.lax.scan(
            group_body, h,
            (params["groups"], gd, state["groups"], kv["k"], kv["v"]))
        new_kv = {"k": ks, "v": vs}
    new_state = dict(state)
    new_state["groups"] = gstates
    new_state["kv"] = new_kv
    if n_tail:
        h, tstates = jax.lax.scan(
            mamba_body, h, (params["tail"], _dget(deltas, "tail"), state["tail"]))
        new_state["tail"] = tstates
    new_state["len"] = state["len"] + 1
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return _logits(params, h, cfg, policy, deltas, matmul_mode), new_state


def _mamba_verify(lp, ld, h_bt, st0, cfg, policy, matmul_mode):
    """One mamba layer over T tokens via the EXACT decode recurrence
    (``block_decode`` scanned token-by-token, so verify states are bitwise
    the states sequential decode would carry). h_bt: (B, T, D). Returns
    (out (B, T, D), final_state, per-step state trajectory (T, ...))."""
    def step(st, h_t):
        h2, st2 = mamba2.block_decode(lp, h_t, st, cfg, policy=policy,
                                      deltas=ld, matmul_mode=matmul_mode)
        return st2, (h2[:, 0], st2)

    st_final, (hs, straj) = jax.lax.scan(
        step, st0, h_bt.transpose(1, 0, 2)[:, :, None, :])
    return hs.transpose(1, 0, 2), st_final, straj


def verify_step(params, state, tokens: jnp.ndarray, cfg: ModelConfig, *,
                policy: QuantPolicy, deltas=None, dtype=jnp.bfloat16,
                matmul_mode: str = "auto", attn_mode: str = "auto"):
    """Multi-token decode against the live state — the speculative verify
    entry point. tokens: (B, T). Returns (logits (B, T, V), new_state,
    trajectory).

    The mamba blocks advance by the exact per-token decode recurrence; the
    shared attention block appends T K/V entries per application and masks
    the draft positions causally against each other and the prefix
    (:func:`repro.models.attention.verify_attention` — the bucketed-prefill
    masking rule on the decode cache). Because SSM states cannot be rewound
    arithmetically, ``trajectory`` snapshots the {"groups"[, "tail"]} state
    subtree after each of the T tokens (leading axis T+1, entry ``j`` =
    state after consuming ``tokens[:, :j]``); :func:`rollback_cache` selects
    each row's accepted entry from it."""
    n_groups, n_tail = _counts(cfg)
    b, t = tokens.shape
    pos0 = jnp.broadcast_to(state["len"], (b,)).astype(jnp.int32)  # (B,)
    quantized = "k_scale" in state["kv"]
    h = embed_lookup(params["embed"], tokens, policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    inv_freq = transformer.rope_freqs(cfg.head_dim, cfg.rope_theta)
    positions = pos0[:, None] + jnp.arange(t)[None, :]             # (B, T)
    rows = jnp.arange(b)[:, None]                                  # (B, 1)
    shared, sdelta = params["shared"], _dget(deltas, "shared")
    from repro.models.attention import verify_attention

    def mamba_body(hh, xs):
        lp, ld, st = xs
        out, st_final, straj = _mamba_verify(lp, ld, hh, st, cfg, policy,
                                             matmul_mode)
        return out, (st_final, straj)

    def group_body(hh, xs):
        if quantized:
            gp, gd, gst, kc, vc, ks_, vs_ = xs
        else:
            gp, gd, gst, kc, vc = xs
            ks_ = vs_ = None
        hh, (gst2, gtraj) = jax.lax.scan(mamba_body, hh, (gp, gd, gst))
        hn = rmsnorm(shared["ln1"], hh, cfg.norm_eps)
        q, k, v = transformer._qkv(shared, hn, cfg, policy, sdelta, positions,
                                   inv_freq, matmul_mode)
        if quantized:
            kq, ksc = transformer._quantize_kv(k)
            vq, vsc = transformer._quantize_kv(v)
            kc = kc.at[rows, positions].set(kq)
            vc = vc.at[rows, positions].set(vq)
            ks_ = ks_.at[rows, positions].set(ksc)
            vs_ = vs_.at[rows, positions].set(vsc)
        else:
            kc = kc.at[rows, positions].set(k.astype(kc.dtype))
            vc = vc.at[rows, positions].set(v.astype(vc.dtype))
        o = verify_attention(q, kc, vc, positions + 1,
                             k_scale=ks_, v_scale=vs_, mode=attn_mode)
        hh = hh + transformer._attn_out(shared, o, cfg, policy, sdelta, b, t,
                                        matmul_mode)
        hn = rmsnorm(shared["ln2"], hh, cfg.norm_eps)
        f, _ = transformer._ffn(shared, hn, cfg, policy, sdelta, matmul_mode)
        out_kv = ((gst2, gtraj, kc, vc, ks_, vs_) if quantized
                  else (gst2, gtraj, kc, vc))
        return hh + f, out_kv

    gd = _dget(deltas, "groups")
    kv = state["kv"]
    if quantized:
        h, (gstates, gtraj, ks, vs, ksc, vsc) = jax.lax.scan(
            group_body, h, (params["groups"], gd, state["groups"],
                            kv["k"], kv["v"], kv["k_scale"], kv["v_scale"]))
        new_kv = {"k": ks, "v": vs, "k_scale": ksc, "v_scale": vsc}
    else:
        h, (gstates, gtraj, ks, vs) = jax.lax.scan(
            group_body, h,
            (params["groups"], gd, state["groups"], kv["k"], kv["v"]))
        new_kv = {"k": ks, "v": vs}
    new_state = dict(state)
    new_state["groups"] = gstates
    new_state["kv"] = new_kv
    # trajectory leaves carry the snapshot axis FIRST: entry j = state after
    # consuming j tokens (entry 0 = the pre-verify state)
    trajectory = {"groups": jax.tree_util.tree_map(
        lambda init, tr: jnp.concatenate([init[None],
                                          jnp.moveaxis(tr, 2, 0)]),
        state["groups"], gtraj)}
    if n_tail:
        h, (tstates, ttraj) = jax.lax.scan(
            mamba_body, h, (params["tail"], _dget(deltas, "tail"),
                            state["tail"]))
        new_state["tail"] = tstates
        trajectory["tail"] = jax.tree_util.tree_map(
            lambda init, tr: jnp.concatenate([init[None],
                                              jnp.moveaxis(tr, 1, 0)]),
            state["tail"], ttraj)
    new_state["len"] = state["len"] + t
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, h, cfg, policy, deltas, matmul_mode)
    return logits, new_state, trajectory


def spec_state_snapshot(state):
    """The state subtree a rollback must restore from per-step snapshots:
    the mamba SSM/conv states ({"groups"[, "tail"]}). The KV part rewinds by
    length like the transformer family and needs no snapshot."""
    snap = {"groups": state["groups"]}
    if "tail" in state:
        snap["tail"] = state["tail"]
    return snap


def _select_state(traj_leaf, j, baxis: int):
    """Per-row snapshot select: traj_leaf (T+1, ...) with the batch axis at
    ``baxis``; j (B,) picks each row's snapshot index. Returns the leaf with
    the snapshot axis removed (batch back at ``baxis - 1``)."""
    moved = jnp.moveaxis(traj_leaf, baxis, 0)          # (B, T+1, ...)
    sel = jax.vmap(lambda tr, idx: tr[idx])(moved, j)  # (B, ...)
    return jnp.moveaxis(sel, 0, baxis - 1)


def rollback_cache(state, slots, new_lens, trajectory=None):
    """Rewind rows ``slots`` (N,) of a slot-major hybrid state to lengths
    ``new_lens`` (N,). KV entries + int8 scales at wiped positions are
    zeroed and ``len`` drops (clamped to [0, current]; zero-distance rewind
    and out-of-range ``slots`` entries are identities), exactly as in the
    transformer family. The mamba states are restored from ``trajectory``
    (from :func:`verify_step` or a draft-chain snapshot stack): row ``b``
    gets snapshot ``new_len[b] - (current_len[b] - T)`` — rows rewound to
    the full current length keep the final (= current) state. With
    ``trajectory=None`` the mamba states are left untouched, which is only
    sound if they never advanced past ``new_lens``."""
    b = state["kv"]["k"].shape[1]
    cur = jnp.broadcast_to(state["len"], (b,)).astype(jnp.int32)
    tgt = cur.at[slots].set(jnp.asarray(new_lens, jnp.int32), mode="drop")
    tgt = jnp.clip(tgt, 0, cur)
    s = state["kv"]["k"].shape[2]
    wipe = transformer._wipe_mask(tgt, cur, s)                     # (B, S)
    out = dict(state)
    kv = dict(state["kv"])
    for name in ("k", "v"):
        kv[name] = jnp.where(wipe[None, :, :, None, None], 0, kv[name])
    if "k_scale" in kv:
        for name in ("k_scale", "v_scale"):
            kv[name] = jnp.where(wipe[None], 0, kv[name])
    out["kv"] = kv
    if trajectory is not None:
        t_steps = jax.tree_util.tree_leaves(trajectory)[0].shape[0] - 1
        j = jnp.clip(tgt - (cur - t_steps), 0, t_steps)
        out["groups"] = jax.tree_util.tree_map(
            lambda tr: _select_state(tr, j, 3), trajectory["groups"])
        if "tail" in trajectory:
            out["tail"] = jax.tree_util.tree_map(
                lambda tr: _select_state(tr, j, 2), trajectory["tail"])
    out["len"] = tgt
    return out


def free_slots(state, slots):
    """Zero rows ``slots`` (N,) of a slot-major hybrid state — KV entries
    (+ int8 scales), mamba group/tail states, and ``len`` — back to the
    freshly-allocated state: the preemption/deadline/quarantine release
    primitive. Batch axes as in :func:`insert_prefill_many`; out-of-range
    entries are dropped (padding convention)."""
    out = dict(state)
    out["groups"] = jax.tree_util.tree_map(
        lambda x: x.at[:, :, slots].set(0, mode="drop"), state["groups"])
    out["kv"] = jax.tree_util.tree_map(
        lambda x: x.at[:, slots].set(0, mode="drop"), state["kv"])
    if "tail" in state:
        out["tail"] = jax.tree_util.tree_map(
            lambda x: x.at[:, slots].set(0, mode="drop"), state["tail"])
    out["len"] = state["len"].at[slots].set(0, mode="drop")
    return out


def insert_prefill(state, slot, src):
    """Copy a single-request prefill state (batch=1, same max_len) into row
    ``slot`` of a slot-major shared state whose ``len`` is per-slot (slots,).
    Batch axes: ``groups`` leaves (G, A, B, ...) -> 2; ``kv``/``tail`` -> 1.
    ``slot`` may be traced."""
    def ins(dst, s, axis):
        return jax.lax.dynamic_update_slice_in_dim(
            dst, s.astype(dst.dtype), slot, axis)

    out = dict(state)
    out["groups"] = jax.tree_util.tree_map(
        lambda dst, s: ins(dst, s, 2), state["groups"], src["groups"])
    out["kv"] = jax.tree_util.tree_map(
        lambda dst, s: ins(dst, s, 1), state["kv"], src["kv"])
    if "tail" in state:
        out["tail"] = jax.tree_util.tree_map(
            lambda dst, s: ins(dst, s, 1), state["tail"], src["tail"])
    out["len"] = jax.lax.dynamic_update_slice(
        state["len"], jnp.reshape(src["len"], (1,)).astype(state["len"].dtype),
        (slot,))
    return out


def insert_prefill_many(state, slot_map, src):
    """Scatter an N-row batched prefill state into rows ``slot_map`` (N,) of
    a slot-major shared state (per-slot ``len``). Batch axes as in
    :func:`insert_prefill`; ``slot_map[i] >= slots`` entries are dropped
    (padding rows)."""
    out = dict(state)
    out["groups"] = jax.tree_util.tree_map(
        lambda dst, s: dst.at[:, :, slot_map].set(s.astype(dst.dtype),
                                                  mode="drop"),
        state["groups"], src["groups"])
    out["kv"] = jax.tree_util.tree_map(
        lambda dst, s: dst.at[:, slot_map].set(s.astype(dst.dtype),
                                               mode="drop"),
        state["kv"], src["kv"])
    if "tail" in state:
        out["tail"] = jax.tree_util.tree_map(
            lambda dst, s: dst.at[:, slot_map].set(s.astype(dst.dtype),
                                                   mode="drop"),
            state["tail"], src["tail"])
    out["len"] = state["len"].at[slot_map].set(
        jnp.asarray(src["len"]).astype(state["len"].dtype), mode="drop")
    return out
