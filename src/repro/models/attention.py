"""Attention: GQA with chunked online-softmax (memory O(seq·chunk), never
materializes the full score matrix) + sliding-window path + decode step.

Shapes: q (B, Lq, H, D); k/v (B, Lkv, KV, D); GQA groups G = H // KV.
The chunked scan keeps running (max, sum, acc) per q position — the standard
flash-attention recurrence expressed in pure JAX (``jax.lax.scan`` over KV
chunks). XLA fuses each chunk's QK^T+softmax+PV; on TPU the same structure is
what a Pallas flash kernel would tile, so the dry-run HLO reflects realistic
memory behaviour at 32k/500k sequence lengths.

Three entry points are kernel-dispatched on ``mode`` ("auto" | "kernel" |
"ref", mirroring ``quant_dense.serve_apply``; 'auto' picks the Pallas
kernel on TPU, the einsum/chunked paths elsewhere):

  * ``decode_attention`` -> ``repro.kernels.attn_decode`` (one q row per
    step, QK^T -> online softmax -> PV in VMEM, per-row cache_len block
    skipping, int8-cache dequant epilogue);
  * ``prefill_attention`` -> ``repro.kernels.attn_prefill`` (blocked
    online-softmax over (q block, key block) tiles; per-row rule: query t
    sees key j iff j <= t AND j < lengths[row], i.e. causal within the
    prompt and the padded tail masked per row; SWA raises the lower bound);
  * ``verify_attention`` -> the same attn_prefill kernel with T = spec_k+1
    query rows and hi = the per-row ``valid`` counts over the live cache.

In every case the ref path is the plain einsum/chunked formulation below,
which the kernel packages' ``ref.py`` oracles match term for term. Masked
softmax rows that are entirely invalid (a zero-valid-length row from engine
padding) produce zeros in both paths — never NaN or the uniform v average.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["chunked_attention", "decode_attention", "prefill_attention",
           "sliding_window_attention", "verify_attention",
           "resolve_attn_mode", "ATTN_MODES"]

NEG_INF = -1e30

ATTN_MODES = ("auto", "kernel", "ref")


def resolve_attn_mode(mode: str) -> str:
    """'auto' -> fused Pallas decode kernel on TPU, einsum path elsewhere."""
    if mode == "auto":
        from repro.kernels.qmatmul.ops import on_tpu
        return "kernel" if on_tpu() else "ref"
    if mode not in ("kernel", "ref"):
        raise ValueError(f"attn mode must be one of {ATTN_MODES}, "
                         f"got {mode!r}")
    return mode


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q (B,Lq,KV,G,D) x k (B,Lc,KV,D) -> (B,KV,G,Lq,Lc), fp32."""
    return jnp.einsum("bqkgd,bckd->bkgqc", q, k, preferred_element_type=jnp.float32)


def _gqa_out(p: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """p (B,KV,G,Lq,Lc) x v (B,Lc,KV,D) -> (B,Lq,KV,G,D)."""
    return jnp.einsum("bkgqc,bckd->bqkgd", p, v)


def _guarded_softmax(sc: jnp.ndarray) -> jnp.ndarray:
    """Softmax over the last axis of NEG_INF-masked fp32 scores with the
    empty-row guard: a row whose every slot is masked would softmax to the
    uniform average over v (exp(NEG_INF - NEG_INF) = 1 per slot — or NaN
    with a true -inf fill); guarded rows produce exact zeros instead,
    matching the attn_decode / attn_prefill kernels."""
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.where(m > NEG_INF / 2, jnp.exp(sc - m), 0.0)
    return p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, chunk: int = 1024,
                      q_offset: int = 0) -> jnp.ndarray:
    """Online-softmax attention over KV chunks.

    ``q_offset``: absolute position of q[0] relative to k[0] (prefill: 0;
    chunked decode batches: cache length).
    """
    b, lq, h, d = q.shape
    _, lkv, kv, _ = k.shape
    g = h // kv
    chunk = min(chunk, lkv)
    nchunks = -(-lkv // chunk)
    pad = nchunks * chunk - lkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = 1.0 / (d ** 0.5)
    qr = (q * scale).reshape(b, lq, kv, g, d)
    kc = k.reshape(b, nchunks, chunk, kv, d)
    vc = v.reshape(b, nchunks, chunk, kv, d)
    q_pos = q_offset + jnp.arange(lq)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, c0 = xs                                    # chunk kv + start idx
        s = _gqa_scores(qr, kb)                            # (B,KV,G,Lq,C)
        kv_pos = c0 + jnp.arange(chunk)
        mask = jnp.broadcast_to(kv_pos[None, :] < lkv, (lq, chunk))  # pad guard
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # empty-row guard: rows with no valid position yet (all-false mask,
        # e.g. a negative q_offset) keep p = 0 instead of exp(0) = 1
        alive = m_new > NEG_INF / 2
        p = jnp.where(alive[..., None], jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.where(alive, jnp.exp(m - m_new), 1.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(v.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    from repro.distributed.context import inner_unroll
    m0 = jnp.full((b, kv, g, lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, lq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, lq, d), jnp.float32)
    starts = jnp.arange(nchunks) * chunk
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), starts),
        unroll=True if inner_unroll() else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, lq, h, d).astype(q.dtype)


def sliding_window_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                             window: int, chunk: int = 1024) -> jnp.ndarray:
    """Causal SWA: each q sees at most ``window`` previous kv. O(L*window).

    Processes q in chunks; per q-chunk slices kv[start-window : start+chunk]
    (static size window+chunk) and runs plain masked attention on the slice.
    """
    b, l, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    chunk = min(chunk, l)
    nq = -(-l // chunk)
    pad = nq * chunk - l
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    span = window + chunk
    # left-pad kv by `window` so every slice is in range
    kp = jnp.pad(k, ((0, 0), (window, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, pad), (0, 0), (0, 0)))
    scale = 1.0 / (d ** 0.5)

    def q_block(i):
        s0 = i * chunk                                      # q block start
        qb = jax.lax.dynamic_slice_in_dim(q, s0, chunk, 1) * scale
        kb = jax.lax.dynamic_slice_in_dim(kp, s0, span, 1)  # abs pos s0-window..
        vb = jax.lax.dynamic_slice_in_dim(vp, s0, span, 1)
        qr = qb.reshape(b, chunk, kv, g, d)
        sc = _gqa_scores(qr, kb)                            # (B,KV,G,chunk,span)
        qpos = s0 + jnp.arange(chunk)
        kpos = s0 - window + jnp.arange(span)
        mask = (kpos[None, :] <= qpos[:, None]) \
            & (kpos[None, :] > qpos[:, None] - window) \
            & (kpos[None, :] >= 0) & (kpos[None, :] < l)
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        p = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
        return _gqa_out(p, vb).reshape(b, chunk, h, d)

    from repro.distributed.context import inner_unroll
    _, out = jax.lax.scan(lambda c, i: (c, q_block(i)), None, jnp.arange(nq),
                          unroll=True if inner_unroll() else 1)  # (nq,B,chunk,H,D)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * chunk, h, d)
    return out[:, :l].astype(q.dtype)


def prefill_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      lengths=None, window: int = 0, mode: str = "auto",
                      chunk: int = 1024,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Prompt self-attention for prefill/admission. q (B, T, H, D) against
    k/v (B, T, KV, D); ``lengths`` (B,) optional per-row valid prompt
    lengths (bucketed admission pads rows up to the bucket); ``window`` > 0
    selects sliding-window masking.

    'kernel' routes to ``repro.kernels.attn_prefill``: blocked online
    softmax — the fp32 score tile never leaves VMEM, no (B, ..., T, T)
    tensor in HBM — with the bucketed-prefill rule applied per row (query t
    sees key j iff j <= t AND j < lengths[row]; SWA additionally requires
    j > t - window) and DMA-level skipping of key blocks past each q
    block's causal frontier. 'ref' is the chunked/SWA scan below; it masks
    causally only — identical at every real query position (j <= t <
    lengths already implies j < lengths), while padded-query rows (t >=
    lengths[row]) may differ; their cache entries are masked downstream by
    per-row lengths and overwritten as the row advances, so decoded tokens
    agree. 'auto' picks the kernel on TPU."""
    if resolve_attn_mode(mode) == "kernel":
        from repro.kernels.attn_prefill.ops import attn_prefill
        b, t = q.shape[0], q.shape[1]
        pos = jnp.arange(t, dtype=jnp.int32)
        hi = jnp.broadcast_to(pos[None, :] + 1, (b, t))
        if lengths is not None:
            lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
            hi = jnp.minimum(hi, lens[:, None])
        lo = None
        if window:
            lo = jnp.broadcast_to(jnp.maximum(pos - (window - 1), 0)[None],
                                  (b, t))
        return attn_prefill(q, k, v, hi, lo=lo, interpret=interpret)
    if window:
        return sliding_window_attention(q, k, v, window=window, chunk=chunk)
    return chunked_attention(q, k, v, causal=True, chunk=chunk)


def verify_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, valid: jnp.ndarray,
                     k_scale=None, v_scale=None, *, mode: str = "auto",
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Multi-token decode attention for speculative verify. q: (B, T, H, D)
    against a (B, S, KV, D) cache; ``valid`` (B, T) is the number of visible
    cache entries per query (its own just-written position included), so the
    T draft positions are causally masked against each other AND against the
    live prefix — the bucketed-prefill masking rule applied to the decode
    cache. Term-for-term the T>1 generalization of :func:`decode_attention`'s
    reference path (same contractions, same int8 per-token scale factoring),
    which keeps verify logits aligned with the sequential decode logits.

    'kernel' routes to ``repro.kernels.attn_prefill`` as its T-row
    specialization (T = spec_k+1, hi = ``valid``): no (B, ..., T, S) score
    tensor in HBM and per-row DMA skipping of cache blocks past the causal
    frontier — S is the full decode cache, so this bounds the verify
    latency that caps speculative throughput. 'ref' is the einsum below
    with the guarded softmax (zero-valid rows produce zeros, not NaN);
    'auto' picks the kernel on TPU."""
    b, t, h, d = q.shape
    if resolve_attn_mode(mode) == "kernel":
        from repro.kernels.attn_prefill.ops import attn_prefill
        return attn_prefill(q, k_cache, v_cache, valid, k_scale=k_scale,
                            v_scale=v_scale, interpret=interpret)
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = 1.0 / (d ** 0.5)
    qr = (q * scale).reshape(b, t, kvh, g, d)
    kc = k_cache if k_scale is None else k_cache.astype(q.dtype)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qr, kc,
                    preferred_element_type=jnp.float32)
    if k_scale is not None:
        sc = sc * k_scale[:, None, None, None, :]
    pos = jnp.arange(s)
    mask = pos[None, None, :] < valid[:, :, None]           # (B, T, S)
    sc = jnp.where(mask[:, None, None], sc, NEG_INF)
    p = _guarded_softmax(sc)
    if v_scale is not None:
        p = (p * v_scale[:, None, None, None, :]).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(q.dtype))
    else:
        p = p.astype(v_cache.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return out.reshape(b, t, h, d).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray, k_scale=None, v_scale=None, *,
                     mode: str = "auto",
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """One-token attention against a (B, S, KV, D) cache. q: (B, 1, H, D).

    ``cache_len``: scalar or (B,) number of valid cache entries. O(S) compute,
    bound by cache bandwidth — the paper's memory-bound regime on TPU.

    int8 cache support: pass per-token ``k_scale``/``v_scale`` (B, S); the
    scales factor exactly through the score and value contractions, so the
    einsums read the int8 arrays directly (half the bf16 cache traffic).

    ``mode`` selects the implementation: 'kernel' runs the fused Pallas
    kernel (``repro.kernels.attn_decode``: blocked online softmax in VMEM —
    no (..., S) score tensor in HBM — per-row valid-length block skipping,
    int8 dequant fused into the epilogue; interpret mode off-TPU, for
    tests), 'ref' the einsum path below, 'auto' (default) kernel on TPU.
    """
    if resolve_attn_mode(mode) == "kernel":
        from repro.kernels.attn_decode.ops import attn_decode
        return attn_decode(q, k_cache, v_cache, cache_len, k_scale, v_scale,
                           interpret=interpret)
    b, _, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = 1.0 / (d ** 0.5)
    qr = (q * scale).reshape(b, 1, kvh, g, d)
    kc = k_cache if k_scale is None else k_cache.astype(q.dtype)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qr, kc,
                    preferred_element_type=jnp.float32)
    if k_scale is not None:
        sc = sc * k_scale[:, None, None, None, :]
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(cache_len)[..., None], (b, s))
    sc = jnp.where(valid[:, None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    if v_scale is not None:
        p = (p * v_scale[:, None, None, None, :]).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(q.dtype))
    else:
        p = p.astype(v_cache.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return out.reshape(b, 1, h, d).astype(q.dtype)
