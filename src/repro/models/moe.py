"""Mixture-of-Experts FFN block (phi3.5-moe 16e/top-2, mixtral-8x22b 8e/top-2).

Capacity-based dense dispatch (Mesh-TF / MaxText style): tokens are grouped,
routed top-k, and moved to (expert, capacity) buffers with one-hot einsums —
the formulation XLA's SPMD partitioner turns into all-to-alls under expert
sharding. Dropping beyond capacity, standard aux load-balancing loss.

Quantization: expert up/gate/down weights carry role 'hidden' (W3 under the
paper's policy); the router is small and sensitive — role 'router' (W8),
mirroring the paper's 8-bit output layer.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant_dense
from repro.core.precision import QuantPolicy
from repro.distributed.context import constrain
from repro.models.layers import act_fn

__all__ = ["moe_init", "moe_apply"]

GROUP_SIZE = 512  # tokens per routing group (keeps dispatch tensors small)


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    scale = 1.0 / (d ** 0.5)
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), dtype) * 0.02},
        "up": {"w": jax.random.uniform(ks[1], (e, d, f), dtype, -1, 1) * scale},
        "down": {"w": jax.random.uniform(ks[2], (e, f, d), dtype, -1, 1) / (f ** 0.5)},
    }
    if cfg.mlp_act == "silu":
        p["gate"] = {"w": jax.random.uniform(ks[3], (e, d, f), dtype, -1, 1) * scale}
    return p


def _expert_weight(params, name, policy: QuantPolicy, deltas) -> jnp.ndarray:
    d = ((deltas or {}).get(name) or {}).get("w") if deltas else None
    return quant_dense.effective_weight(params[name], policy, "hidden", d)


def moe_apply(params: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig, *,
              policy: QuantPolicy, deltas: Optional[Dict] = None,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    g = min(GROUP_SIZE, t)
    ng = t // g if t % g == 0 else 1
    if t % g != 0:                      # tiny smoke shapes: single group
        g = t
    xg = x.reshape(ng, g, d)

    rd = ((deltas or {}).get("router") or {}).get("w") if deltas else None
    wr = quant_dense.effective_weight(params["router"], policy, "router", rd)
    logits = jnp.einsum("ngd,de->nge", xg, wr.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (ng,g,E) fp32
    top_p, top_i = jax.lax.top_k(probs, k)                      # (ng,g,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum(frac_tokens * frac_probs)
    density = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=1)
    density_p = jnp.mean(probs, axis=1)
    aux = jnp.mean(jnp.sum(density * density_p, axis=-1)) * (e ** 2) / k

    cap = max(1, int(cfg.capacity_factor * g * k / e))
    # choice-major flattening: choice 0 of every token outranks choice 1
    sel = jax.nn.one_hot(top_i.transpose(0, 2, 1).reshape(ng, k * g), e,
                         dtype=jnp.int32)                       # (ng, kg, E)
    pos = jnp.cumsum(sel, axis=1) - 1                           # position in expert
    keep = (pos < cap) & (sel > 0)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, -1), cap, dtype=x.dtype)
    disp = sel.astype(x.dtype)[..., None] * pos_oh              # (ng,kg,E,C)
    disp = constrain(disp, "moe_dispatch")

    wts = top_p.transpose(0, 2, 1).reshape(ng, k * g).astype(x.dtype)
    comb = disp * wts[..., None, None]                          # (ng,kg,E,C)

    xk = jnp.tile(xg, (1, k, 1))                                # (ng, kg, d)
    buf = jnp.einsum("nte,ntd->ned", disp.reshape(ng, k * g, e * cap), xk)
    buf = buf.reshape(ng, e, cap, d)
    buf = constrain(buf, "moe_buffer")

    act = act_fn(cfg.mlp_act)
    w_up = _expert_weight(params, "up", policy, deltas).astype(x.dtype)
    w_dn = _expert_weight(params, "down", policy, deltas).astype(x.dtype)
    h = jnp.einsum("necd,edf->necf", buf, w_up)
    if "gate" in params:
        w_gt = _expert_weight(params, "gate", policy, deltas).astype(x.dtype)
        h = act(jnp.einsum("necd,edf->necf", buf, w_gt)) * h
    else:
        h = act(h)
    out_buf = jnp.einsum("necf,efd->necd", h, w_dn)
    out_buf = constrain(out_buf, "moe_buffer")

    yk = jnp.einsum("nte,ned->ntd", comb.reshape(ng, k * g, e * cap),
                    out_buf.reshape(ng, e * cap, d))            # (ng, kg, d)
    y = jnp.sum(yk.reshape(ng, k, g, d), axis=1)
    return y.reshape(b, s, d), aux.astype(jnp.float32)
