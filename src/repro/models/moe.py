"""Mixture-of-Experts FFN block (phi3.5-moe 16e/top-2, mixtral-8x22b 8e/top-2).

Capacity-based dense dispatch (Mesh-TF / MaxText style): tokens are grouped,
routed top-k, and moved to (expert, capacity) buffers with one-hot einsums —
the formulation XLA's SPMD partitioner turns into all-to-alls under expert
sharding. Dropping beyond capacity, standard aux load-balancing loss.

Quantization: expert up/gate/down weights carry role 'hidden' (W3 under the
paper's policy); the router is small and sensitive — role 'router' (W8),
mirroring the paper's 8-bit output layer.

Serve forms route through the unified kernel dispatch: the router (2D) goes
through ``quant_dense.apply``; the 3D expert tensors ('kernel' mode) are
swept with one Pallas qmatmul per expert under ``lax.map`` — the weight is
expanded only in VMEM — while 'dequant' mode matmuls the int8 levels in the
activation dtype and rescales the OUTPUT buffer by delta, so neither mode
materializes a dequantized expert matrix.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant_dense
from repro.core.precision import QuantPolicy
from repro.distributed.context import constrain
from repro.models.layers import act_fn

__all__ = ["moe_init", "moe_apply"]

GROUP_SIZE = 512  # tokens per routing group (keeps dispatch tensors small)


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    scale = 1.0 / (d ** 0.5)
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), dtype) * 0.02},
        "up": {"w": jax.random.uniform(ks[1], (e, d, f), dtype, -1, 1) * scale},
        "down": {"w": jax.random.uniform(ks[2], (e, f, d), dtype, -1, 1) / (f ** 0.5)},
    }
    if cfg.mlp_act == "silu":
        p["gate"] = {"w": jax.random.uniform(ks[3], (e, d, f), dtype, -1, 1) * scale}
    return p


def _expert_matmul(params, name, buf: jnp.ndarray, policy: QuantPolicy,
                   deltas, mm: str) -> jnp.ndarray:
    """buf (ng, E, C, K) x expert stack (E, K, F) -> (ng, E, C, F), weight-
    form aware. Serve forms never materialize a dequantized expert matrix."""
    leaf = params[name]
    if isinstance(leaf, dict) and "q" in leaf:
        q, delta = leaf["q"], leaf["delta"]          # (E, K, F), (E, 1, F)
        e = q.shape[0]
        if quant_dense.resolve_matmul_mode(mm) == "kernel":
            from repro.kernels.qmatmul import ops as qmm_ops
            ng, _, cap, k = buf.shape
            xb = buf.transpose(1, 0, 2, 3).reshape(e, ng * cap, k)
            # delta may be per-layer (1, 1, F) or per-expert (E, 1, F)
            de = jnp.broadcast_to(delta, (e, 1, q.shape[-1]))
            y = jax.lax.map(
                lambda ex: qmm_ops.qmatmul(ex[0], ex[1], ex[2].reshape(-1)),
                (xb, q, de))
            return y.reshape(e, ng, cap, -1).transpose(1, 0, 2, 3)
        acc = jnp.einsum("necd,edf->necf", buf, q.astype(buf.dtype),
                         preferred_element_type=jnp.float32)
        return (acc * delta[None].astype(jnp.float32)).astype(buf.dtype)
    d = ((deltas or {}).get(name) or {}).get("w") if deltas else None
    w = quant_dense.effective_weight(leaf, policy, "hidden", d)
    return jnp.einsum("necd,edf->necf", buf, w.astype(buf.dtype))


def moe_apply(params: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig, *,
              policy: QuantPolicy, deltas: Optional[Dict] = None,
              matmul_mode: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    g = min(GROUP_SIZE, t)
    ng = t // g if t % g == 0 else 1
    if t % g != 0:                      # tiny smoke shapes: single group
        g = t
    xg = x.reshape(ng, g, d)

    if isinstance(params["router"], dict) and "q" in params["router"]:
        # out_dtype=fp32: the router is the 'small and sensitive' component —
        # rounding its logits through bf16 activations could flip near-tie
        # top_k routing vs the float-weight branch below
        logits = quant_dense.serve_apply(params["router"], xg,
                                         mode=matmul_mode,
                                         out_dtype=jnp.float32)
    else:
        rd = ((deltas or {}).get("router") or {}).get("w") if deltas else None
        wr = quant_dense.effective_weight(params["router"], policy, "router", rd)
        logits = jnp.einsum("ngd,de->nge", xg, wr.astype(x.dtype),
                            preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (ng,g,E) fp32
    top_p, top_i = jax.lax.top_k(probs, k)                      # (ng,g,k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum(frac_tokens * frac_probs)
    density = jnp.mean(jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=1)
    density_p = jnp.mean(probs, axis=1)
    aux = jnp.mean(jnp.sum(density * density_p, axis=-1)) * (e ** 2) / k

    cap = max(1, int(cfg.capacity_factor * g * k / e))
    # choice-major flattening: choice 0 of every token outranks choice 1
    sel = jax.nn.one_hot(top_i.transpose(0, 2, 1).reshape(ng, k * g), e,
                         dtype=jnp.int32)                       # (ng, kg, E)
    pos = jnp.cumsum(sel, axis=1) - 1                           # position in expert
    keep = (pos < cap) & (sel > 0)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, -1), cap, dtype=x.dtype)
    disp = sel.astype(x.dtype)[..., None] * pos_oh              # (ng,kg,E,C)
    disp = constrain(disp, "moe_dispatch")

    wts = top_p.transpose(0, 2, 1).reshape(ng, k * g).astype(x.dtype)
    comb = disp * wts[..., None, None]                          # (ng,kg,E,C)

    xk = jnp.tile(xg, (1, k, 1))                                # (ng, kg, d)
    buf = jnp.einsum("nte,ntd->ned", disp.reshape(ng, k * g, e * cap), xk)
    buf = buf.reshape(ng, e, cap, d)
    buf = constrain(buf, "moe_buffer")

    act = act_fn(cfg.mlp_act)
    h = _expert_matmul(params, "up", buf, policy, deltas, matmul_mode)
    if "gate" in params:
        hg = _expert_matmul(params, "gate", buf, policy, deltas, matmul_mode)
        h = act(hg) * h
    else:
        h = act(h)
    out_buf = _expert_matmul(params, "down", h, policy, deltas, matmul_mode)
    out_buf = constrain(out_buf, "moe_buffer")

    yk = jnp.einsum("nte,ned->ntd", comb.reshape(ng, k * g, e * cap),
                    out_buf.reshape(ng, e * cap, d))            # (ng, kg, d)
    y = jnp.sum(yk.reshape(ng, k, g, d), axis=1)
    return y.reshape(b, s, d), aux.astype(jnp.float32)
