"""Unified model interface: ``get_model(cfg)`` returns the family module.

Every module exposes:
    init(key, cfg, dtype)                               -> params
    forward(params, batch, cfg, *, policy, deltas, ...) -> (logits, aux)
    prefill(params, batch, cfg, *, policy, ...)         -> (logits, cache)
    decode_step(params, cache, tokens, cfg, *, policy)  -> (logits, cache)
    insert_prefill(cache, slot, src)                    -> cache
    init_cache/init_state(cfg, batch, max_len, ...)     -> cache

``decode_step`` is batched: ``cache["len"]`` may be a scalar (uniform batch,
e.g. ``generate``) or a (B,) vector of per-row lengths, in which case every
batch row is an independent request at its own position — the slot-major
layout the continuous-batching engine uses. ``insert_prefill`` copies a
single-request prefill cache into one slot of such a shared cache; the
module-level helper here additionally takes ``cfg`` first to dispatch:
``insert_prefill(cfg, cache, slot, src)``.

``prefill`` is batched too: ``prefill(..., lengths=(B,))`` runs N
right-padded prompts of distinct true lengths in one call — logits come
from each row's last real token, ``cache["len"]`` is per-row, and family
internals (attention masking, SSM recurrence, conv tail) are padding-exact.
``insert_prefill_many(cfg, cache, slot_map, src)`` scatters all N rows of
such a batched prefill into the shared cache in one jitted op; rows whose
``slot_map`` entry is >= slots are dropped (batch padding).

``forward``/``prefill``/``decode_step`` additionally take
``matmul_mode="auto"|"kernel"|"dequant"`` (threaded to every quantized
matmul via ``quant_dense``): with serve-form params ({"q"} levels / {"qp"}
packed containers) 'kernel' runs the Pallas qmatmul/qmatvec kernels (weights
expanded only in VMEM), 'dequant' runs the fused levels-matmul fallback, and
'auto' picks 'kernel' on TPU. Neither serve mode materializes a dequantized
fp32 weight matrix in the graph.

The attention-bearing families (everything but ``ssm``) take two more
serving knobs. ``attn_mode="auto"|"kernel"|"ref"`` dispatches EVERY
attention serving path between its Pallas kernel and the einsum/chunked
reference: ``decode_step`` between the fused ``kernels.attn_decode``
kernel and the einsum ref, and ``prefill`` / ``verify_step`` between the
blocked online-softmax ``kernels.attn_prefill`` kernel (the (T, S) score
tile stays in VMEM — no quadratic score tensor in HBM; per-row
bucketed-prefill masking) and the chunked / guarded-einsum refs
(``models.attention.prefill_attention`` / ``verify_attention``). And
``prefill(..., quantize_cache=True)`` / ``init_cache(..., kv_bits=8)``
store the KV cache as int8 values + per-token fp32 scales (half the cache
bytes per slot); all attention paths read the quantized cache directly
under either attn_mode.

Speculative decoding adds three entry points (transformer-family + hybrid;
``ssm`` raises — its SSD state folds every token irreversibly):

    verify_step(params, cache, tokens (B,T), cfg, ...)
        -> (logits (B,T,V), cache, trajectory)
        causal-masked multi-token decode against the live cache: position
        ``t``'s logits match what sequential ``decode_step`` would produce
        after ``tokens[:, :t+1]``. ``trajectory`` is the per-step state
        snapshot stack rollback needs (None for the stateless-KV families).
    rollback_cache(cfg, cache, slots, new_lens, trajectory=None)
        per-row rewind of rejected draft suffixes: ``len`` drops, wiped KV
        entries + int8 scales are zeroed (exact un-write), hybrid mamba
        states are restored from ``trajectory``. Zero-distance rewinds and
        out-of-range ``slots`` entries are identities.
    spec_state_snapshot(cfg, cache)
        the subtree rollback restores from snapshots (None when a length
        rewind suffices) — what a draft chain stacks per step.

``draft_of(cfg, params)`` derives the speculative DRAFTER from any
checkpoint: the packed 3-bit ``qp`` serve form of the same weights (the
paper's near-free fixed-point network), optionally depth-sliced.
"""
from __future__ import annotations

import dataclasses
from types import ModuleType
from typing import Optional

import jax

from repro.configs.base import ModelConfig
from repro.models import hybrid, mamba2, transformer

__all__ = ["get_model", "init_cache", "init_cache_abstract", "prefill",
           "decode_step", "verify_step", "rollback_cache",
           "spec_state_snapshot", "draft_of", "insert_prefill",
           "insert_prefill_many", "free_slots", "cache_to_host",
           "cache_from_host"]

_FAMILY_MODULE = {
    "dense": transformer, "audio": transformer, "vlm": transformer,
    "moe": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
}


def get_model(cfg: ModelConfig) -> ModuleType:
    return _FAMILY_MODULE[cfg.family]


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None, *,
               per_slot_len: bool = False, kv_bits: Optional[int] = None):
    """Decode cache/state for ``batch`` rows. With ``per_slot_len`` the
    ``len`` entry is a (batch,) int32 vector — one length per slot — which is
    what the batched ``decode_step`` path and ``insert_prefill`` expect.

    ``kv_bits=8`` allocates the KV cache as int8 + per-token fp32 scales
    (transformer-family and hybrid; ``ssm`` has no KV cache and raises)."""
    import jax.numpy as jnp

    if kv_bits not in (None, 8):
        raise ValueError(f"kv_bits must be None or 8, got {kv_bits!r}")
    dtype = dtype or jnp.bfloat16
    mod = get_model(cfg)
    if cfg.family == "ssm":
        if kv_bits:
            raise ValueError("kv_bits=8 is meaningless for family 'ssm': "
                             "it has no KV cache to quantize")
        cache = mod.init_state(cfg, batch, max_len, dtype)
    else:
        cache = mod.init_cache(cfg, batch, max_len, dtype,
                               quantized=kv_bits == 8)
    if per_slot_len:
        cache["len"] = jnp.zeros((batch,), jnp.int32)
    return cache


def init_cache_abstract(cfg: ModelConfig, batch: int, max_len: int,
                        dtype=None, *, per_slot_len: bool = False,
                        kv_bits: Optional[int] = None):
    """The ShapeDtypeStruct tree of ``init_cache`` without allocating any
    device memory — what the static-analysis contract registry
    (``repro.analysis.contracts``) feeds abstract eval. Same validation,
    same structure, zero bytes."""
    import functools

    return jax.eval_shape(functools.partial(
        init_cache, cfg, batch, max_len, dtype,
        per_slot_len=per_slot_len, kv_bits=kv_bits))


def prefill(params, batch, cfg: ModelConfig, **kw):
    return get_model(cfg).prefill(params, batch, cfg, **kw)


def decode_step(params, cache, tokens, cfg: ModelConfig, **kw):
    return get_model(cfg).decode_step(params, cache, tokens, cfg, **kw)


def verify_step(params, cache, tokens, cfg: ModelConfig, **kw):
    """Multi-token decode against the live cache (speculative verify).
    Returns (logits (B,T,V), new_cache, trajectory). ``ssm`` raises."""
    return get_model(cfg).verify_step(params, cache, tokens, cfg, **kw)


def rollback_cache(cfg: ModelConfig, cache, slots, new_lens, trajectory=None):
    """Rewind rows ``slots`` to ``new_lens`` — undo rejected draft
    suffixes. See the module docstring for the exact semantics; ``ssm``
    raises (SSD state can't rewind)."""
    return get_model(cfg).rollback_cache(cache, slots, new_lens, trajectory)


def spec_state_snapshot(cfg: ModelConfig, cache):
    """Per-step snapshot subtree a draft chain must stack for rollback
    (None for the pure-KV families). ``ssm`` raises."""
    return get_model(cfg).spec_state_snapshot(cache)


def draft_of(cfg: ModelConfig, params, *, policy=None,
             depth_fraction: float = 1.0):
    """Derive a speculative DRAFTER from any checkpoint, no second training
    run: returns ``(draft_cfg, draft_params)`` where the params are the
    packed 3-bit ``qp`` serve form (``quant_dense.export_container``) of
    the same weights — the paper's nearly-free fixed-point network, reused
    as the model that drafts for its own full-precision master copy.

    ``depth_fraction < 1`` additionally slices the leading stacked-layer
    axis (transformer/ssm: ``layers``; hybrid: whole mamba+attention
    ``groups``, keeping the tail) for a cheaper, lower-acceptance drafter —
    the bench's half-depth variant. Params already in a serve form
    ({"q"}/{"qp"} leaves) are depth-sliced but not re-exported."""
    from repro.core import quant_dense
    from repro.core.precision import W3A8

    if not 0.0 < depth_fraction <= 1.0:
        raise ValueError(f"depth_fraction must be in (0, 1], "
                         f"got {depth_fraction}")
    draft_cfg, draft_params = cfg, params
    if depth_fraction < 1.0:
        if cfg.family == "hybrid":
            n_groups = cfg.num_layers // cfg.attn_every
            keep = max(1, int(n_groups * depth_fraction))
            draft_params = dict(params)
            draft_params["groups"] = jax.tree_util.tree_map(
                lambda x: x[:keep], params["groups"])
            draft_cfg = dataclasses.replace(
                cfg, num_layers=keep * cfg.attn_every
                + cfg.num_layers % cfg.attn_every)
        else:
            keep = max(1, int(cfg.num_layers * depth_fraction))
            draft_params = dict(params)
            draft_params["layers"] = jax.tree_util.tree_map(
                lambda x: x[:keep], params["layers"])
            draft_cfg = dataclasses.replace(cfg, num_layers=keep)
    if not quant_dense.is_serve_form(draft_params):
        draft_params = quant_dense.export_container(draft_params,
                                                    policy or W3A8)
    return draft_cfg, draft_params


def free_slots(cfg: ModelConfig, cache, slots):
    """Zero rows ``slots`` (N,) of a slot-major cache/state back to the
    freshly-allocated state (``len`` 0, all entries 0) — the release
    primitive behind slot preemption, deadline cancellation, and NaN
    quarantine. Every family supports it (unlike ``rollback_cache``: a
    full release needs no trajectory — zero IS the SSD initial state).
    Out-of-range entries are dropped, matching ``insert_prefill_many``;
    the committed-token snapshot a preemption requeues with is host-side
    (``Request.prompt + Request.out``), so nothing is read back here."""
    return get_model(cfg).free_slots(cache, slots)


def cache_to_host(cfg: ModelConfig, cache):
    """Snapshot a device cache/state tree to host numpy, dtype- and
    structure-preserving — ONE bulk ``device_get`` for the whole tree (the
    engine's async-drain discipline applies to durability too: no
    per-leaf sync). The result round-trips exactly through
    :func:`cache_from_host`: KV entries (bf16 or int8), per-token int8-KV
    scale planes, SSM/conv state, SWA ring contents and per-slot ``len``
    vectors all come back bit-identical, which is what makes a restored
    engine's continuation token-identical rather than merely close."""
    del cfg                        # families share the tree-of-arrays layout
    return jax.device_get(cache)


def cache_from_host(cfg: ModelConfig, host_cache, *, like=None):
    """Re-materialize a :func:`cache_to_host` snapshot on device.

    ``like`` (a live cache tree or ``init_cache_abstract`` result) makes
    the restore VALIDATING: structure, shapes and dtypes must match the
    engine's allocated cache exactly, so restoring a snapshot from a
    mismatched config (different slots/max_len/kv_bits/family) fails
    loudly at restore time instead of corrupting decode later."""
    import jax.numpy as jnp
    import numpy as np

    if like is not None:
        flat_h = jax.tree_util.tree_leaves_with_path(host_cache)
        flat_l = jax.tree_util.tree_leaves_with_path(like)
        paths_h = [jax.tree_util.keystr(p) for p, _ in flat_h]
        paths_l = [jax.tree_util.keystr(p) for p, _ in flat_l]
        if paths_h != paths_l:
            raise ValueError(
                f"cache snapshot structure mismatch for {cfg.name}: "
                f"snapshot has {paths_h}, engine expects {paths_l}")
        for (p, h), (_, ref) in zip(flat_h, flat_l):
            h = np.asarray(h)
            if h.shape != ref.shape or h.dtype != np.dtype(ref.dtype):
                raise ValueError(
                    f"cache snapshot leaf {jax.tree_util.keystr(p)} is "
                    f"{h.shape}/{h.dtype}, engine expects "
                    f"{ref.shape}/{np.dtype(ref.dtype)} — snapshot was "
                    f"taken under a different engine config")
    return jax.tree_util.tree_map(jnp.asarray, host_cache)


def insert_prefill(cfg: ModelConfig, cache, slot, src):
    return get_model(cfg).insert_prefill(cache, slot, src)


def insert_prefill_many(cfg: ModelConfig, cache, slot_map, src):
    return get_model(cfg).insert_prefill_many(cache, slot_map, src)
