"""Unified model interface: ``get_model(cfg)`` returns the family module.

Every module exposes:
    init(key, cfg, dtype)                               -> params
    forward(params, batch, cfg, *, policy, deltas, ...) -> (logits, aux)
    prefill(params, batch, cfg, *, policy, ...)         -> (logits, cache)
    decode_step(params, cache, tokens, cfg, *, policy)  -> (logits, cache)
    init_cache/init_state(cfg, batch, max_len, ...)     -> cache
"""
from __future__ import annotations

from types import ModuleType

from repro.configs.base import ModelConfig
from repro.models import hybrid, mamba2, transformer

__all__ = ["get_model", "init_cache"]

_FAMILY_MODULE = {
    "dense": transformer, "audio": transformer, "vlm": transformer,
    "moe": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
}


def get_model(cfg: ModelConfig) -> ModuleType:
    return _FAMILY_MODULE[cfg.family]


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    import jax.numpy as jnp

    dtype = dtype or jnp.bfloat16
    mod = get_model(cfg)
    if cfg.family == "ssm":
        return mod.init_state(cfg, batch, max_len, dtype)
    return mod.init_cache(cfg, batch, max_len, dtype)
