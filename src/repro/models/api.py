"""Unified model interface: ``get_model(cfg)`` returns the family module.

Every module exposes:
    init(key, cfg, dtype)                               -> params
    forward(params, batch, cfg, *, policy, deltas, ...) -> (logits, aux)
    prefill(params, batch, cfg, *, policy, ...)         -> (logits, cache)
    decode_step(params, cache, tokens, cfg, *, policy)  -> (logits, cache)
    insert_prefill(cache, slot, src)                    -> cache
    init_cache/init_state(cfg, batch, max_len, ...)     -> cache

``decode_step`` is batched: ``cache["len"]`` may be a scalar (uniform batch,
e.g. ``generate``) or a (B,) vector of per-row lengths, in which case every
batch row is an independent request at its own position — the slot-major
layout the continuous-batching engine uses. ``insert_prefill`` copies a
single-request prefill cache into one slot of such a shared cache; the
module-level helper here additionally takes ``cfg`` first to dispatch:
``insert_prefill(cfg, cache, slot, src)``.

``prefill`` is batched too: ``prefill(..., lengths=(B,))`` runs N
right-padded prompts of distinct true lengths in one call — logits come
from each row's last real token, ``cache["len"]`` is per-row, and family
internals (attention masking, SSM recurrence, conv tail) are padding-exact.
``insert_prefill_many(cfg, cache, slot_map, src)`` scatters all N rows of
such a batched prefill into the shared cache in one jitted op; rows whose
``slot_map`` entry is >= slots are dropped (batch padding).

``forward``/``prefill``/``decode_step`` additionally take
``matmul_mode="auto"|"kernel"|"dequant"`` (threaded to every quantized
matmul via ``quant_dense``): with serve-form params ({"q"} levels / {"qp"}
packed containers) 'kernel' runs the Pallas qmatmul/qmatvec kernels (weights
expanded only in VMEM), 'dequant' runs the fused levels-matmul fallback, and
'auto' picks 'kernel' on TPU. Neither serve mode materializes a dequantized
fp32 weight matrix in the graph.

The attention-bearing families (everything but ``ssm``) take two more
serving knobs: ``decode_step(..., attn_mode="auto"|"kernel"|"ref")``
dispatches decode attention between the fused Pallas
``kernels.attn_decode`` kernel and the einsum reference
(``models.attention.decode_attention``), and
``prefill(..., quantize_cache=True)`` / ``init_cache(..., kv_bits=8)``
store the KV cache as int8 values + per-token fp32 scales (half the cache
bytes per slot); the decode paths read the quantized cache directly under
either attn_mode.
"""
from __future__ import annotations

from types import ModuleType
from typing import Optional

from repro.configs.base import ModelConfig
from repro.models import hybrid, mamba2, transformer

__all__ = ["get_model", "init_cache", "prefill", "decode_step",
           "insert_prefill", "insert_prefill_many"]

_FAMILY_MODULE = {
    "dense": transformer, "audio": transformer, "vlm": transformer,
    "moe": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
}


def get_model(cfg: ModelConfig) -> ModuleType:
    return _FAMILY_MODULE[cfg.family]


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None, *,
               per_slot_len: bool = False, kv_bits: Optional[int] = None):
    """Decode cache/state for ``batch`` rows. With ``per_slot_len`` the
    ``len`` entry is a (batch,) int32 vector — one length per slot — which is
    what the batched ``decode_step`` path and ``insert_prefill`` expect.

    ``kv_bits=8`` allocates the KV cache as int8 + per-token fp32 scales
    (transformer-family and hybrid; ``ssm`` has no KV cache and raises)."""
    import jax.numpy as jnp

    if kv_bits not in (None, 8):
        raise ValueError(f"kv_bits must be None or 8, got {kv_bits!r}")
    dtype = dtype or jnp.bfloat16
    mod = get_model(cfg)
    if cfg.family == "ssm":
        if kv_bits:
            raise ValueError("kv_bits=8 is meaningless for family 'ssm': "
                             "it has no KV cache to quantize")
        cache = mod.init_state(cfg, batch, max_len, dtype)
    else:
        cache = mod.init_cache(cfg, batch, max_len, dtype,
                               quantized=kv_bits == 8)
    if per_slot_len:
        cache["len"] = jnp.zeros((batch,), jnp.int32)
    return cache


def prefill(params, batch, cfg: ModelConfig, **kw):
    return get_model(cfg).prefill(params, batch, cfg, **kw)


def decode_step(params, cache, tokens, cfg: ModelConfig, **kw):
    return get_model(cfg).decode_step(params, cache, tokens, cfg, **kw)


def insert_prefill(cfg: ModelConfig, cache, slot, src):
    return get_model(cfg).insert_prefill(cache, slot, src)


def insert_prefill_many(cfg: ModelConfig, cache, slot_map, src):
    return get_model(cfg).insert_prefill_many(cache, slot_map, src)
