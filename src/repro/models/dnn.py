"""The paper's feed-forward DNN (§2.1): 784-1022-1022-1022-10 (digit) and
429-1022x4-61 (phoneme), sigmoid hidden units.

This is the faithful-reproduction model: W3 hidden layers, W8 output layer,
8-bit signals between layers (policy.act_bits=8), biases full precision. The
``sigmoid_mode`` flag selects the exact sigmoid or the piecewise-linear
approximation (paper ref [16] — implemented in kernels/sigmoid_pw with a jnp
oracle used here).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import qat, quant_dense
from repro.core.precision import QuantPolicy

__all__ = ["init", "forward", "num_params"]


def init(key, input_dim: int, hidden: Sequence[int], num_classes: int,
         dtype=jnp.float32) -> Dict[str, Any]:
    dims = [input_dim, *hidden, num_classes]
    ks = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        # Glorot's sigmoid gain: sigmoid(x) ~ 0.5 + x/4 attenuates signals 4x
        # per layer; x4 init keeps unit gain through the 3-4 hidden layers
        # (without it the 1022-wide net sits on the symmetric plateau).
        layers.append(quant_dense.init(ks[i], a, b, bias=True, dtype=dtype,
                                       scale=4.0 / (a ** 0.5)))
    # the classifier is named 'head' so path-based role inference (treeutil.
    # role_of) applies the paper's sensitive-output rule (8-bit) everywhere
    names = [f"fc{i}" for i in range(len(layers) - 1)] + ["head"]
    return dict(zip(names, layers))


def _sigmoid(x: jnp.ndarray, mode: str) -> jnp.ndarray:
    if mode == "exact":
        return jax.nn.sigmoid(x)
    from repro.kernels.sigmoid_pw import ref as sig_ref
    return sig_ref.sigmoid_pw(x)


def forward(params: Dict[str, Any], x: jnp.ndarray, *, policy: QuantPolicy,
            deltas: Optional[Dict] = None, sigmoid_mode: str = "exact",
            ) -> jnp.ndarray:
    """x: (B, input_dim) -> logits (B, classes).

    Layer roles follow the paper exactly: every hidden matrix is 'hidden'
    (3-bit under W3A8), the final classifier is 'output' (8-bit)."""
    n = len(params)
    d = deltas or {}
    h = x
    names = [f"fc{i}" for i in range(n - 1)] + ["head"]
    for i, name in enumerate(names):
        role = "output" if name == "head" else "hidden"
        h = quant_dense.apply(params[name], h, policy=policy, role=role,
                              delta=(d.get(name) or {}).get("w"))
        if i < n - 1:
            h = _sigmoid(h, sigmoid_mode)
            if policy.act_bits:                # paper: 8-bit signals, in [0,1]
                h = qat.fake_quant_act(h, policy.act_bits, signed=False)
    return h


def num_params(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
