from repro.models.api import get_model, init_cache

__all__ = ["get_model", "init_cache"]
