from repro.models.api import (decode_step, get_model, init_cache,
                              insert_prefill, prefill)

__all__ = ["get_model", "init_cache", "prefill", "decode_step",
           "insert_prefill"]
