"""Decoder-only transformer backbone (dense / audio / vlm / moe families).

Features per assigned-arch requirements: GQA (num_kv_heads < num_heads),
qk_norm (qwen3), QKV bias (qwen2/2.5), sliding-window attention (mixtral),
RoPE, tied embeddings, MoE FFN (phi3.5/mixtral), frontend-embedding prefix
([audio]/[vlm] stubs). Layers run under ``jax.lax.scan`` with stacked params
(compile once per layer — mandatory at 64L/512-device lowering scale) and
optional remat.

Every projection goes through ``quant_dense`` so the paper's W3A8 policy
applies: wq/wk/wv/wo + FFN are role 'hidden' (3-bit), embed role 'embed',
LM head role 'output' (8-bit, the paper's sensitive-layer rule).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant_dense
from repro.core.precision import QuantPolicy
from repro.distributed.context import constrain
from repro.models import moe as moe_mod
from repro.models.attention import (decode_attention, prefill_attention,
                                    resolve_attn_mode, verify_attention)
from repro.models.layers import (apply_rope, embed_init, embed_lookup,
                                 head_rmsnorm, logits_readout, mlp_apply,
                                 mlp_init, rmsnorm, rmsnorm_init, rope_freqs)

__all__ = ["init", "forward", "init_cache", "prefill", "decode_step",
           "verify_step", "rollback_cache", "spec_state_snapshot",
           "insert_prefill", "insert_prefill_many"]


# --- init -----------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": quant_dense.init(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": quant_dense.init(ks[1], d, kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": quant_dense.init(ks[2], d, kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": quant_dense.init(ks[3], h * hd, d, bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((hd,), jnp.float32)}
    return p


def _layer_init(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    p = {"ln1": rmsnorm_init(cfg.d_model), "ln2": rmsnorm_init(cfg.d_model),
         "attn": _attn_init(ks[0], cfg, dtype)}
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act, dtype)
    return p


def init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys)
    params = {"embed": embed_init(ks[1], cfg.vocab_size, cfg.d_model, dtype),
              "layers": layers, "final_norm": rmsnorm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["head"] = quant_dense.init(ks[2], cfg.d_model, cfg.vocab_size,
                                          bias=False, dtype=dtype)
    return params


# --- attention block --------------------------------------------------------------

def _dget(deltas, *names):
    node = deltas
    for n in names:
        if node is None:
            return None
        node = node.get(n)
    return node


def _qkv(lp, h, cfg: ModelConfig, policy, deltas, positions, inv_freq,
         mm: str = "auto"):
    b, s, _ = h.shape
    hd = cfg.head_dim
    q = quant_dense.apply(lp["attn"]["wq"], h, policy=policy, role="hidden",
                          delta=_dget(deltas, "attn", "wq", "w"), mode=mm)
    k = quant_dense.apply(lp["attn"]["wk"], h, policy=policy, role="hidden",
                          delta=_dget(deltas, "attn", "wk", "w"), mode=mm)
    v = quant_dense.apply(lp["attn"]["wv"], h, policy=policy, role="hidden",
                          delta=_dget(deltas, "attn", "wv", "w"), mode=mm)
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(lp["attn"]["q_norm"]["scale"], q, cfg.norm_eps)
        k = head_rmsnorm(lp["attn"]["k_norm"]["scale"], k, cfg.norm_eps)
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)
    return q, k, v


def _attn_out(lp, o, cfg, policy, deltas, b, s, mm: str = "auto"):
    o = o.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return quant_dense.apply(lp["attn"]["wo"], o, policy=policy, role="hidden",
                             delta=_dget(deltas, "attn", "wo", "w"), mode=mm)


def _ffn(lp, h, cfg: ModelConfig, policy, deltas, mm: str = "auto"):
    """Returns (out, aux_loss)."""
    if cfg.family == "moe":
        return moe_mod.moe_apply(lp["moe"], h, cfg, policy=policy,
                                 deltas=_dget(deltas, "moe"), matmul_mode=mm)
    out = mlp_apply(lp["mlp"], h, act=cfg.mlp_act, policy=policy,
                    deltas=_dget(deltas, "mlp"), matmul_mode=mm)
    return out, jnp.zeros((), jnp.float32)


def _layer_forward(lp, ld, h, cfg: ModelConfig, policy, positions, inv_freq,
                   attn_chunk: int, mm: str = "auto", attn_mode: str = "ref",
                   lengths=None):
    """``attn_mode``/``lengths`` select the prefill-attention path: 'kernel'
    is the blocked Pallas kernel with the per-row bucketed-prefill mask
    (j <= t AND j < lengths[row]); 'ref' (the training default) the chunked
    / SWA scans, causal-only."""
    b, s, _ = h.shape
    hn = rmsnorm(lp["ln1"], h, cfg.norm_eps)
    q, k, v = _qkv(lp, hn, cfg, policy, ld, positions, inv_freq, mm)
    o = prefill_attention(q, k, v, lengths=lengths,
                          window=cfg.sliding_window or 0, mode=attn_mode,
                          chunk=min(attn_chunk, s))
    h = h + _attn_out(lp, o, cfg, policy, ld, b, s, mm)
    h = constrain(h, "act")
    hn = rmsnorm(lp["ln2"], h, cfg.norm_eps)
    f, aux = _ffn(lp, hn, cfg, policy, ld, mm)
    h = constrain(h + f, "act")
    return h, aux, (k, v)


# --- full forward (train) ----------------------------------------------------------

def _embed_input(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
                 policy, deltas, dtype):
    """Token embeddings, with frontend prefix for [audio]/[vlm] stubs."""
    h = embed_lookup(params["embed"], batch["tokens"], policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    if cfg.frontend is not None and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(dtype)
        h = jnp.concatenate([fe, h], axis=1)
    return h


def forward(params: Dict[str, Any], batch: Dict[str, jnp.ndarray],
            cfg: ModelConfig, *, policy: QuantPolicy,
            deltas: Optional[Dict] = None, dtype=jnp.bfloat16,
            remat: str = "layer", attn_chunk: int = 1024,
            matmul_mode: str = "auto",
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/eval forward. Returns (logits (B,S,V) fp32, aux_loss)."""
    h = _embed_input(params, batch, cfg, policy, deltas, dtype)
    h = constrain(h, "act")
    s = h.shape[1]
    positions = jnp.arange(s)[None, :]
    inv_freq = rope_freqs(cfg.head_dim, cfg.rope_theta)

    def body(carry, xs):
        hh, aux = carry
        lp, ld = xs
        hh, a, _ = _layer_forward(lp, ld, hh, cfg, policy, positions, inv_freq,
                                  attn_chunk, matmul_mode)
        return (hh, aux + a), None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    ld = deltas.get("layers") if deltas else None
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               (params["layers"], ld))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, h, cfg, policy, deltas, matmul_mode)
    return logits, aux


def _logits(params, h, cfg, policy, deltas, mm: str = "auto"):
    return logits_readout(params, h, cfg, policy=policy,
                          embed_delta=_dget(deltas, "embed", "w"),
                          head_delta=_dget(deltas, "head", "w"),
                          matmul_mode=mm)


# --- serving: prefill + decode ------------------------------------------------------

def cache_len_for(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               quantized: bool = False):
    """KV cache. ``quantized``: int8 entries + per-(layer,batch,position)
    fp32 scales — the paper's on-chip-quantization principle applied to the
    decode cache, which dominates decode HBM traffic at long context
    (beyond-paper, §Perf H-kv8). Scales factor exactly through attention."""
    s = cache_len_for(cfg, max_len)
    shape = (cfg.num_layers, batch, s, cfg.num_kv_heads, cfg.head_dim)
    if quantized:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros((cfg.num_layers, batch, s), jnp.float32),
                "v_scale": jnp.zeros((cfg.num_layers, batch, s), jnp.float32),
                "len": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


def _quantize_kv(x: jnp.ndarray):
    """(B, S, KV, D) -> (int8 values, (B, S) scales). Per-token absmax."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-2, -1))
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def prefill(params, batch, cfg: ModelConfig, *, policy: QuantPolicy,
            deltas: Optional[Dict] = None, dtype=jnp.bfloat16,
            attn_chunk: int = 1024, max_len: Optional[int] = None,
            quantize_cache: bool = False,
            lengths: Optional[jnp.ndarray] = None,
            matmul_mode: str = "auto", attn_mode: str = "auto"):
    """Run the prompt, build the KV cache. Returns (last_logits, cache).

    ``lengths`` (B,) enables right-padded multi-request prefill: row ``i``
    holds a prompt of true length ``lengths[i]`` left-aligned in the padded
    (B, S) token array. Causal attention means valid positions never see the
    padding; the returned logits are gathered at each row's last REAL token
    and ``cache["len"]`` is the per-row true length, so decode overwrites /
    masks the junk K/V at padded positions. Requires S <= cache length (the
    sliding-window ring-roll path is per-row-ambiguous under padding).

    ``attn_mode`` ("auto" | "kernel" | "ref") picks the prompt
    self-attention implementation — the blocked online-softmax Pallas
    kernel (``kernels.attn_prefill``: no (B, ..., S, S) score tensor in
    HBM, per-row length masking) or the chunked/SWA reference scans (see
    :func:`repro.models.attention.prefill_attention`).
    """
    attn_mode = resolve_attn_mode(attn_mode)
    h = _embed_input(params, batch, cfg, policy, deltas, dtype)
    s = h.shape[1]
    max_len = max_len or s
    cs = cache_len_for(cfg, max_len)
    if lengths is not None and s > cs:
        raise ValueError(f"padded prefill length {s} exceeds cache length "
                         f"{cs}; per-row ring alignment is undefined")
    positions = jnp.arange(s)[None, :]
    inv_freq = rope_freqs(cfg.head_dim, cfg.rope_theta)

    def body(hh, xs):
        lp, ld = xs
        hh, _, (k, v) = _layer_forward(lp, ld, hh, cfg, policy, positions,
                                       inv_freq, attn_chunk, matmul_mode,
                                       attn_mode, lengths)
        # keep last `cs` positions (ring-start for SWA, whole seq otherwise)
        return hh, (k[:, -cs:], v[:, -cs:])

    ld = deltas.get("layers") if deltas else None
    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], ld))
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        h = jnp.take_along_axis(h, (lengths - 1)[:, None, None], axis=1)
    else:
        h = h[:, -1:]
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, h, cfg, policy, deltas, matmul_mode)
    if cs > ks.shape[2]:
        padw = cs - ks.shape[2]
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, padw), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, padw), (0, 0), (0, 0)))
    elif cfg.sliding_window and s >= cs and s % cs:
        # ring-buffer invariant: token t lives at slot t % cs. The slice put
        # token s-cs+i at slot i; roll by s % cs so it sits at (s+i) % cs.
        ks = jnp.roll(ks, s % cs, axis=2)
        vs = jnp.roll(vs, s % cs, axis=2)
    clen = jnp.asarray(s, jnp.int32) if lengths is None else lengths
    if quantize_cache:
        qk, sk = jax.vmap(_quantize_kv)(ks)       # over layer dim
        qv, sv = jax.vmap(_quantize_kv)(vs)
        cache = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv, "len": clen}
    else:
        cache = {"k": ks, "v": vs, "len": clen}
    return logits, cache


def decode_step(params, cache, tokens: jnp.ndarray, cfg: ModelConfig, *,
                policy: QuantPolicy, deltas: Optional[Dict] = None,
                dtype=jnp.bfloat16, matmul_mode: str = "auto",
                attn_mode: str = "auto"):
    """One token for the whole batch. tokens: (B, 1) int32.

    Returns (logits (B,1,V), new_cache). The KV cache is a ring buffer for
    SWA archs (bounded window) and an append buffer otherwise; rope uses the
    absolute position so ring overwrites stay correct.

    ``cache["len"]`` may be a scalar (uniform batch, e.g. ``generate``) or a
    (B,) vector of per-row lengths (slot-major continuous batching: every row
    is an independent request at its own position).

    ``attn_mode`` ("auto" | "kernel" | "ref") picks the decode-attention
    implementation — the fused Pallas ``kernels.attn_decode`` kernel or the
    einsum reference (see :func:`repro.models.attention.decode_attention`);
    it reads the int8 cache (``k_scale`` present) either way.
    """
    b = tokens.shape[0]
    pos = jnp.broadcast_to(cache["len"], (b,)).astype(jnp.int32)   # (B,)
    quantized = "k_scale" in cache
    h = embed_lookup(params["embed"], tokens, policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    h = constrain(h, "dec_act")
    inv_freq = rope_freqs(cfg.head_dim, cfg.rope_theta)
    positions = pos[:, None]                                       # (B, 1)
    cs = cache["k"].shape[2]
    slot = jnp.mod(pos, cs) if cfg.sliding_window else pos
    rows = jnp.arange(b)

    def body(hh, xs):
        if quantized:
            lp, ld, kc, vc, ks_, vs_ = xs
        else:
            lp, ld, kc, vc = xs
            ks_ = vs_ = None
        hn = rmsnorm(lp["ln1"], hh, cfg.norm_eps)
        q, k, v = _qkv(lp, hn, cfg, policy, ld, positions, inv_freq,
                       matmul_mode)
        if quantized:
            kq, ksc = _quantize_kv(k)
            vq, vsc = _quantize_kv(v)
            kc = kc.at[rows, slot].set(kq[:, 0])
            vc = vc.at[rows, slot].set(vq[:, 0])
            ks_ = ks_.at[rows, slot].set(ksc[:, 0])
            vs_ = vs_.at[rows, slot].set(vsc[:, 0])
        else:
            kc = kc.at[rows, slot].set(k[:, 0].astype(kc.dtype))
            vc = vc.at[rows, slot].set(v[:, 0].astype(vc.dtype))
        valid = jnp.minimum(pos + 1, cs)
        o = decode_attention(q, kc, vc, valid, k_scale=ks_, v_scale=vs_,
                             mode=attn_mode)
        hh = hh + _attn_out(lp, o, cfg, policy, ld, b, 1, matmul_mode)
        hn = rmsnorm(lp["ln2"], hh, cfg.norm_eps)
        f, _ = _ffn(lp, hn, cfg, policy, ld, matmul_mode)
        out = (hh + f, (kc, vc, ks_, vs_) if quantized else (kc, vc))
        return out

    ld = deltas.get("layers") if deltas else None
    if quantized:
        h, (ks, vs, ksc, vsc) = jax.lax.scan(
            body, h, (params["layers"], ld, cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        new_cache = {"k": ks, "v": vs, "k_scale": ksc, "v_scale": vsc,
                     "len": cache["len"] + 1}
    else:
        h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], ld, cache["k"],
                                             cache["v"]))
        new_cache = {"k": ks, "v": vs, "len": cache["len"] + 1}
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, h, cfg, policy, deltas, matmul_mode)
    return logits, new_cache


def verify_step(params, cache, tokens: jnp.ndarray, cfg: ModelConfig, *,
                policy: QuantPolicy, deltas: Optional[Dict] = None,
                dtype=jnp.bfloat16, matmul_mode: str = "auto",
                attn_mode: str = "auto"):
    """Multi-token decode against the live cache — the speculative-decoding
    verify entry point. tokens: (B, T) int32, the T tokens to append
    (committed last token + T-1 draft tokens).

    Returns (logits (B, T, V), new_cache, trajectory=None): position ``t``'s
    logits are the distribution over the token FOLLOWING ``tokens[:, t]`` —
    exactly what ``decode_step`` would have produced after consuming
    ``tokens[:, :t+1]`` sequentially. K/V for all T positions are written
    into the cache (``len`` advances by T); rejected suffixes are undone with
    :func:`rollback_cache`. Attention uses the causal per-row masking of the
    bucketed-prefill path applied to the decode cache
    (:func:`repro.models.attention.verify_attention`); ``attn_mode``
    ("auto" | "kernel" | "ref") dispatches it between the blocked
    ``kernels.attn_prefill`` Pallas kernel (T = spec_k+1 query rows, no
    (B, ..., T, S) score tensor in HBM, per-row DMA skipping past the
    causal frontier) and the guarded masked-einsum reference. The trailing
    ``None`` is the rollback trajectory slot (only stateful families need
    one — see hybrid).
    """
    b, t = tokens.shape
    pos0 = jnp.broadcast_to(cache["len"], (b,)).astype(jnp.int32)  # (B,)
    quantized = "k_scale" in cache
    h = embed_lookup(params["embed"], tokens, policy=policy,
                     delta=_dget(deltas, "embed", "w"), dtype=dtype)
    h = constrain(h, "dec_act")
    inv_freq = rope_freqs(cfg.head_dim, cfg.rope_theta)
    positions = pos0[:, None] + jnp.arange(t)[None, :]             # (B, T)
    cs = cache["k"].shape[2]
    slot = jnp.mod(positions, cs) if cfg.sliding_window else positions
    rows = jnp.arange(b)[:, None]                                  # (B, 1)

    def body(hh, xs):
        if quantized:
            lp, ld, kc, vc, ks_, vs_ = xs
        else:
            lp, ld, kc, vc = xs
            ks_ = vs_ = None
        hn = rmsnorm(lp["ln1"], hh, cfg.norm_eps)
        q, k, v = _qkv(lp, hn, cfg, policy, ld, positions, inv_freq,
                       matmul_mode)
        if quantized:
            kq, ksc = _quantize_kv(k)
            vq, vsc = _quantize_kv(v)
            kc = kc.at[rows, slot].set(kq)
            vc = vc.at[rows, slot].set(vq)
            ks_ = ks_.at[rows, slot].set(ksc)
            vs_ = vs_.at[rows, slot].set(vsc)
        else:
            kc = kc.at[rows, slot].set(k.astype(kc.dtype))
            vc = vc.at[rows, slot].set(v.astype(vc.dtype))
        valid = jnp.minimum(positions + 1, cs)                     # (B, T)
        o = verify_attention(q, kc, vc, valid, k_scale=ks_, v_scale=vs_,
                             mode=attn_mode)
        hh = hh + _attn_out(lp, o, cfg, policy, ld, b, t, matmul_mode)
        hn = rmsnorm(lp["ln2"], hh, cfg.norm_eps)
        f, _ = _ffn(lp, hn, cfg, policy, ld, matmul_mode)
        out = (hh + f, (kc, vc, ks_, vs_) if quantized else (kc, vc))
        return out

    ld = deltas.get("layers") if deltas else None
    if quantized:
        h, (ks, vs, ksc, vsc) = jax.lax.scan(
            body, h, (params["layers"], ld, cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        new_cache = {"k": ks, "v": vs, "k_scale": ksc, "v_scale": vsc,
                     "len": cache["len"] + t}
    else:
        h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], ld, cache["k"],
                                             cache["v"]))
        new_cache = {"k": ks, "v": vs, "len": cache["len"] + t}
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = _logits(params, h, cfg, policy, deltas, matmul_mode)
    return logits, new_cache, None


def _wipe_mask(tgt: jnp.ndarray, cur: jnp.ndarray, cs: int) -> jnp.ndarray:
    """(B, S) bool: cache slots holding positions in [tgt, cur) per row —
    the entries a rollback erases. Ring-aware: position ``p`` lives at slot
    ``p % cs``, so the wiped band is the cyclic interval starting at
    ``tgt % cs`` of width ``cur - tgt`` (rewinds never span more than the
    ring — the engine forbids speculating across a ring wrap)."""
    sidx = jnp.arange(cs)
    return (jnp.mod(sidx[None, :] - tgt[:, None], cs)
            < (cur - tgt)[:, None])


def spec_state_snapshot(cache):
    """The subtree a rollback must restore from per-step snapshots. The
    transformer-family cache is pure KV — a length rewind suffices — so
    there is nothing to snapshot."""
    return None


def rollback_cache(cache, slots, new_lens, trajectory=None):
    """Rewind rows ``slots`` (N,) of a slot-major cache to lengths
    ``new_lens`` (N,) — the speculative-decoding rejection primitive.

    Semantics: per selected row, ``len`` drops to ``new_lens`` (clamped to
    [0, current]; a zero-distance rewind is the identity) and the K/V
    entries + int8 per-token scales at the wiped positions are zeroed, so
    the rolled-back cache is exactly the cache that never saw the rejected
    tokens. Rows whose ``slots`` entry is out of range are dropped (the
    engine's padding convention); ``trajectory`` is accepted for signature
    parity (stateful families use it) and must be None here."""
    assert trajectory is None, "transformer-family cache has no state trajectory"
    b = cache["k"].shape[1]
    cur = jnp.broadcast_to(cache["len"], (b,)).astype(jnp.int32)
    tgt = cur.at[slots].set(jnp.asarray(new_lens, jnp.int32), mode="drop")
    tgt = jnp.clip(tgt, 0, cur)
    cs = cache["k"].shape[2]
    wipe = _wipe_mask(tgt, cur, cs)                                # (B, S)
    out = dict(cache)
    for name in ("k", "v"):
        out[name] = jnp.where(wipe[None, :, :, None, None], 0, cache[name])
    if "k_scale" in cache:
        for name in ("k_scale", "v_scale"):
            out[name] = jnp.where(wipe[None], 0, cache[name])
    out["len"] = tgt
    return out


def free_slots(cache, slots):
    """Zero rows ``slots`` (N,) of a slot-major cache and reset their
    ``len`` to 0 — the release primitive behind preemption, deadline
    cancellation and NaN quarantine. The freed rows are exactly the
    freshly-allocated state (so a later ``insert_prefill_many`` admission
    is indistinguishable from first use, and a quarantined row's
    non-finite K/V entries cannot linger). Entries with ``slots[i] >=
    batch`` are dropped (the engine's padding convention)."""
    out = dict(cache)
    names = ("k", "v") + (("k_scale", "v_scale") if "k_scale" in cache else ())
    for name in names:                       # leaves (L, slots, ...): axis 1
        out[name] = cache[name].at[:, slots].set(0, mode="drop")
    out["len"] = cache["len"].at[slots].set(0, mode="drop")
    return out


def insert_prefill(cache, slot, src):
    """Copy a single-request prefill cache (batch=1, same max_len) into row
    ``slot`` of a slot-major shared cache whose ``len`` is per-slot (slots,).

    ``slot`` may be a traced int32 scalar, so one jitted insert serves every
    slot without recompiling. Purely functional: returns the updated cache.
    """
    out = dict(cache)
    for name in ("k", "v"):
        out[name] = jax.lax.dynamic_update_slice_in_dim(
            cache[name], src[name].astype(cache[name].dtype), slot, 1)
    if "k_scale" in cache:
        for name in ("k_scale", "v_scale"):
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], src[name], slot, 1)
    out["len"] = jax.lax.dynamic_update_slice(
        cache["len"], jnp.reshape(src["len"], (1,)).astype(cache["len"].dtype),
        (slot,))
    return out


def insert_prefill_many(cache, slot_map, src):
    """Scatter an N-row batched prefill cache into rows ``slot_map`` (N,) of
    a slot-major shared cache (per-slot ``len``). One jitted scatter admits
    every request at once; entries with ``slot_map[i] >= slots`` are dropped
    (JAX scatter OOB semantics) — the engine points padding rows there.
    """
    out = dict(cache)
    names = ("k", "v") + (("k_scale", "v_scale") if "k_scale" in cache else ())
    for name in names:                       # leaves (L, slots, ...): axis 1
        out[name] = cache[name].at[:, slot_map].set(
            src[name].astype(cache[name].dtype), mode="drop")
    out["len"] = cache["len"].at[slot_map].set(
        jnp.asarray(src["len"]).astype(cache["len"].dtype), mode="drop")
    return out
