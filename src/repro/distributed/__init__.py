from repro.distributed.context import constrain, sharding_rules

__all__ = ["constrain", "sharding_rules"]
