"""Gradient compression for slow (cross-pod) links: int8 quantization with
error feedback (DESIGN §8).

The paper's insight applied to collectives: gradients, like weights, tolerate
aggressive quantization if the error is fed back — the same
train-time-quantization principle as step 3 of the paper, applied to the
all-reduce payload. 4x fewer bytes over the pod axis, and the residual is
carried to the next step so the compression bias vanishes in expectation.

``make_grad_compressor`` returns a ``grad_transform`` for
training.loop.make_train_step: grads are quantized int8 (per-leaf absmax
scale), dequantized, and the quantization residual is stored in the train
state under "ef" (created lazily on first use).

On a real multi-pod mesh the int8 payload is what crosses the pod axis: the
transform runs *before* XLA's data-parallel all-reduce in the gradient
computation graph, so the all-reduce operand is the dequantized-int8 tensor —
with ``shard_map``-level manual collectives (see ``compressed_psum``) the
wire format is literally int8.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_grad", "dequantize_grad", "make_grad_compressor",
           "compressed_psum"]


def quantize_grad(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_grad(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def make_grad_compressor():
    """grad_transform(grads, state) -> (grads', state') with error feedback."""

    def transform(grads, state):
        ef = state.get("ef")
        if ef is None:
            ef = jax.tree_util.tree_map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        def comp(g, e):
            g = g.astype(jnp.float32) + e
            q, s = quantize_grad(g)
            gq = dequantize_grad(q, s)
            return gq, g - gq

        flat = jax.tree_util.tree_map(comp, grads, ef)
        gq = jax.tree_util.tree_map(lambda t: t[0], flat,
                                    is_leaf=lambda t: isinstance(t, tuple))
        ef2 = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda t: isinstance(t, tuple))
        new_state = dict(state)
        new_state["ef"] = ef2
        return gq, new_state

    return transform


@partial(jax.jit, static_argnames=("axis_name",))
def _psum_int8(q, scale, axis_name):
    # int32 accumulate of int8 payloads (wire bytes = 1/4 of fp32), scales
    # averaged — a ring all-reduce over `axis_name` carries int8 shards.
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s = jax.lax.pmean(scale, axis_name)
    return total.astype(jnp.float32) * s


def compressed_psum(g: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """shard_map-level compressed all-reduce (use inside shard_map over the
    pod axis): quantize locally, psum int8 payloads, dequantize."""
    q, s = quantize_grad(g)
    return _psum_int8(q, s, axis_name)
