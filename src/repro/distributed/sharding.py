"""Sharding rules: parameter PartitionSpecs by path + activation-constraint
tables, for the production meshes (DESIGN §8).

Axes: ``data`` (+ ``pod`` when multi-pod) = data parallel; ``model`` = tensor
parallel (Megatron pattern), expert parallel (MoE, when E % model == 0), and
sequence sharding for decode KV caches.

All rules are **divisibility-guarded**: a dim is only sharded if the axis size
divides it; otherwise the next candidate (or replication) applies. That is
what lets a single rule set serve 10 architectures (GQA kv=2/8/32, MoE E=8/16,
vocab 92553, SSD heads 80, ...) on a 16-way model axis without per-arch
special cases.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.treeutil import map_with_path

__all__ = ["dp_axes", "param_specs", "state_specs", "batch_specs",
           "activation_rules", "cache_specs", "tree_shardings", "axis_size"]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.axis_names else 1


def _guarded(shape: Sequence[int], mesh: Mesh,
             candidates: Sequence[Tuple[int, Any]]) -> P:
    """First candidate (dim, axis) whose axis size divides shape[dim] wins.
    ``dim`` may be negative (counted from the end) — rules are written
    against the *logical* weight, so stacked leading layer dims (L,) or
    (G, attn_every) don't change them."""
    spec = [None] * len(shape)
    for dim, axis in candidates:
        d = dim % len(shape)
        if shape[d] % axis_size(mesh, axis) == 0 and spec[d] is None:
            spec[d] = axis
            return P(*spec)
    return P(*spec)


def param_specs(cfg: ModelConfig, params_tree: Any, mesh: Mesh,
                fsdp: bool = False) -> Any:
    """PartitionSpec tree mirroring ``params_tree`` (works on ShapeDtypeStruct
    templates from jax.eval_shape — the dry-run path).

    ``fsdp=True`` additionally shards every >=2D weight over the ``data``
    axis on a free dim (ZeRO-3 semantics: XLA all-gathers per layer in
    fwd/bwd, reduce-scatters grads). Mandatory for the >=8B trains — fp32
    master + Adam moments replicated across 16 data rows do not fit 16GB."""
    kv_shardable = (cfg.num_kv_heads and
                    cfg.num_kv_heads % axis_size(mesh, "model") == 0)
    ep = cfg.num_experts and cfg.num_experts % axis_size(mesh, "model") == 0

    def rule(path: str, leaf) -> P:
        s = leaf.shape
        p = path.lower()
        if len(s) == 0:
            return P()
        # ---- embeddings / head -------------------------------------------------
        if p.endswith("embed/w"):
            return _guarded(s, mesh, [(0, "model"), (1, "model")])
        if "head/w" in p:
            return _guarded(s, mesh, [(-1, "model"), (-2, "model")])
        # ---- attention ---------------------------------------------------------
        if "attn/wq/w" in p or "attn/wq/b" in p:
            return _guarded(s, mesh, [(-1, "model")])
        if "attn/wk/" in p or "attn/wv/" in p:
            if kv_shardable:
                return _guarded(s, mesh, [(-1, "model")])
            return P(*([None] * len(s)))          # replicate small GQA kv
        if "attn/wo/w" in p:
            return _guarded(s, mesh, [(-2, "model")])
        # ---- MoE ---------------------------------------------------------------
        if "moe/router" in p:
            return P(*([None] * len(s)))
        if "moe/up/w" in p or "moe/gate/w" in p:    # (.., E, d, f)
            cand = [(-3, "model"), (-1, "model")] if ep else [(-1, "model")]
            return _guarded(s, mesh, cand)
        if "moe/down/w" in p:                       # (.., E, f, d)
            cand = [(-3, "model"), (-2, "model")] if ep else [(-2, "model")]
            return _guarded(s, mesh, cand)
        # ---- dense MLP -----------------------------------------------------------
        if "mlp/up/w" in p or "mlp/gate/w" in p:
            return _guarded(s, mesh, [(-1, "model")])
        if "mlp/down/w" in p:
            return _guarded(s, mesh, [(-2, "model")])
        # ---- mamba2 ----------------------------------------------------------------
        if "in_proj/w" in p:
            return _guarded(s, mesh, [(-1, "model")])
        if "/wz/w" in p or "/wx/w" in p:          # split projections (H-split)
            return _guarded(s, mesh, [(-1, "model")])
        if "/wbc/" in p or "/wdt/" in p:          # tiny: replicate
            return P(*([None] * len(s)))
        if "out_proj/w" in p:
            return _guarded(s, mesh, [(-2, "model")])
        if "conv_bc" in p:
            return P(*([None] * len(s)))
        if "conv_x" in p or "conv_w" in p or "conv_b" in p:
            return _guarded(s, mesh, [(-1, "model")])
        # ---- everything else (norms, biases, ssm dynamics, deltas) -----------------
        return P(*([None] * len(s)))

    def add_fsdp(spec: P, leaf) -> P:
        s = leaf.shape
        if len(s) < 2 or "data" not in mesh.axis_names:
            return spec
        parts = list(spec) + [None] * (len(s) - len(spec))
        if "data" in parts:
            return spec
        # prefer the matrix dim not already model-sharded, innermost first
        for d in (-2, -1, -3):
            d2 = d % len(s)
            if d2 < len(s) - 2 and len(s) == 2:
                continue
            if parts[d2] is None and s[d2] % axis_size(mesh, "data") == 0:
                parts[d2] = "data"
                return P(*parts)
        return spec

    def rule_dispatch(path, leaf):
        # quantized-serve leaves: {"q"| "qp", "delta"} follow the weight rule
        if path.endswith("/q") or path.endswith("/qp"):
            spec = rule(path[: path.rfind("/")] + "/w", leaf)
        elif path.endswith("/delta"):
            return P(*([None] * len(leaf.shape)))
        else:
            spec = rule(path, leaf)
        if fsdp and (path.endswith("/w") or path.endswith("/q")):
            spec = add_fsdp(spec, leaf)
        return spec

    return map_with_path(rule_dispatch, params_tree)


def state_specs(cfg: ModelConfig, state_tree: Any, mesh: Mesh,
                fsdp: bool = False) -> Any:
    """Train-state specs: params + optimizer moments (same layout) + scalars."""
    pspecs = param_specs(cfg, state_tree["params"], mesh, fsdp=fsdp)
    out = {"params": pspecs, "step": P()}
    if "opt" in state_tree:
        opt = {}
        for k, v in state_tree["opt"].items():
            if k == "count":
                opt[k] = P()
            else:   # moments mirror the param layout exactly
                opt[k] = param_specs(cfg, v, mesh, fsdp=fsdp)
        out["opt"] = opt
    if "deltas" in state_tree:
        out["deltas"] = map_with_path(
            lambda p, l: P(*([None] * len(l.shape))), state_tree["deltas"])
    return out


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                batch_tree: Any) -> Any:
    dp = dp_axes(mesh)
    shardable = shape.global_batch % axis_size(mesh, dp) == 0

    def rule(path, leaf):
        spec = [None] * len(leaf.shape)
        if shardable and len(leaf.shape) >= 1:
            spec[0] = dp
        return P(*spec)

    return map_with_path(rule, batch_tree)


def activation_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Dict:
    """Constraint table for distributed.context.sharding_rules."""
    dp = dp_axes(mesh)
    bs = shape.global_batch % axis_size(mesh, dp) == 0
    b = dp if bs else None
    ep = cfg.num_experts and cfg.num_experts % axis_size(mesh, "model") == 0
    vs = cfg.vocab_size % axis_size(mesh, "model") == 0
    return {
        "act": P(b, None, None),
        "dec_act": P(b, None, None),
        "logits": P(b, None, "model" if vs else None),
        "moe_dispatch": P(b, None, "model" if ep else None, None),
        "moe_buffer": P(b, "model" if ep else None, None, None),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                cache_tree: Any) -> Any:
    """KV-cache / SSM-state specs for serving.

    Transformer cache leaves: (L, B, S, KV, D) — batch over dp when it
    divides, **sequence over model** (the only way a 1.1TB 32k x 128 cache
    fits per-device HBM; softmax over the sharded axis becomes an XLA
    all-reduce pair, see DESIGN §8). Hybrid kv: (n_apps, B, S, KV, D).
    SSM states: (L, B, H, P, N) — heads over model.
    """
    dp = dp_axes(mesh)
    bs = shape.global_batch % axis_size(mesh, dp) == 0
    b = dp if bs else None

    def rule(path, leaf):
        s = leaf.shape
        if path.endswith("len") or len(s) <= 1:
            return P(*([None] * len(s)))
        if path.endswith("_scale"):                      # int8 kv per-token scales
            spec = [None] * len(s)
            spec[-2] = b                                 # (L, B, S)
            if s[-1] % axis_size(mesh, "model") == 0:
                spec[-1] = "model"
            return P(*spec)
        if path in ("k", "v") or path.endswith("/k") or path.endswith("/v"):
            spec = [None] * len(s)
            spec[1] = b                                  # batch
            if not bs and s[2] % axis_size(mesh, "data") == 0:
                spec[2] = ("data", "model") if s[2] % axis_size(
                    mesh, ("data", "model")) == 0 else "data"
            elif s[2] % axis_size(mesh, "model") == 0:
                spec[2] = "model"                        # sequence over model
            return P(*spec)
        if "/ssm" in path:                               # (L.., B, H, P, N)
            spec = [None] * len(s)
            spec[-4] = b
            if s[-3] % axis_size(mesh, "model") == 0:
                spec[-3] = "model"
            return P(*spec)
        if "/conv" in path:                              # (L.., B, W-1, C)
            spec = [None] * len(s)
            spec[-3] = b
            if s[-1] % axis_size(mesh, "model") == 0:
                spec[-1] = "model"
            return P(*spec)
        return P(*([None] * len(s)))

    return map_with_path(rule, cache_tree)


def tree_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return map_with_path(
        lambda p, s: NamedSharding(mesh, s) if s is not None else None,
        spec_tree)
