"""Sharding-constraint context: models stay mesh-agnostic.

Step builders install a {name: PartitionSpec} table; model code calls
``constrain(x, "act")`` at strategic points. Outside a mesh/step-builder
context it is a no-op, so smoke tests on one CPU device run unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax

_state = threading.local()


def _table() -> Optional[Dict]:
    return getattr(_state, "table", None)


@contextlib.contextmanager
def sharding_rules(table: Dict):
    prev = _table()
    _state.table = table
    try:
        yield
    finally:
        _state.table = prev


# --- cost-exact tracing mode ---------------------------------------------------
# XLA's HloCostAnalysis counts while-loop bodies once. The dry-run's reduced
# -depth cost lowerings trace under this flag so models UNROLL their inner
# chunk loops (attention KV chunks, SSD chunks, hybrid inner layer scan) and
# flops/bytes come out exact. Never set for real execution or full compiles.

@contextlib.contextmanager
def cost_exact_mode():
    prev = getattr(_state, "cost_exact", False)
    _state.cost_exact = True
    try:
        yield
    finally:
        _state.cost_exact = prev


def is_cost_exact() -> bool:
    return getattr(_state, "cost_exact", False)


def inner_unroll() -> bool:
    """unroll= argument for inner lax.scans in model code."""
    return bool(is_cost_exact())


def constrain(x, name: str):
    table = _table()
    if not table or name not in table:
        return x
    spec = table[name]
    if spec is None:
        return x
    mesh = table.get("__mesh__")
    if mesh is not None:
        # divisibility guard: drop axes that don't divide the dim (lets one
        # rule table serve every shape incl. tiny smoke/decode shapes)
        import numpy as np
        from jax.sharding import PartitionSpec as P

        def size(ax):
            if ax is None:
                return 1
            if isinstance(ax, (tuple, list)):
                return int(np.prod([mesh.shape[a] for a in ax]))
            return mesh.shape[ax]

        parts = list(spec) + [None] * (x.ndim - len(spec))
        parts = [a if (d % size(a) == 0 and size(a) > 1) else None
                 for d, a in zip(x.shape, parts)]
        if all(a is None for a in parts):
            return x
        spec = P(*parts)
    return jax.lax.with_sharding_constraint(x, spec)
