"""Optional pipeline parallelism (DESIGN §8): GPipe-style microbatch pipeline
over a ``stage`` mesh axis, built on shard_map + lax.ppermute.

Each stage device holds one stage's params (stacked on a leading stage dim
outside shard_map). The schedule runs ``n_micro + n_stages - 1`` ticks; at
tick t, stage s processes microbatch ``t - s`` (bubble fraction =
(S-1)/(T+S-1)). ``ppermute`` moves activations stage->stage+1 — on real
hardware this is the neighbor ICI link, the cheapest collective there is.

Differentiable: jax AD transposes ppermute to the reverse permutation, so
``jax.grad`` through ``pipeline_apply`` yields the backward pipeline
(GPipe semantics: full activation stash, no interleaving).

The production dry-run meshes use DP x TP (pod/data/model); this module is
the composable PP option for depth-dominated models — enable by adding a
``stage`` axis to the mesh and scanning each stage's layers inside
``stage_fn``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn: Callable, stage_params, x_micro: jnp.ndarray,
                   mesh: Mesh, axis: str = "stage") -> jnp.ndarray:
    """Run microbatches through a linear pipeline.

    stage_fn(params_one_stage, x: (B, ...)) -> (B, ...)   same in/out shape
    stage_params: pytree with leading stage dim == mesh.shape[axis]
    x_micro: (n_micro, B, ...) microbatched input
    Returns (n_micro, B, ...) outputs (valid on every device after the final
    gather — replicated for simplicity).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1
    fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params, xs):
        params = jax.tree_util.tree_map(lambda p: p[0], params)  # my stage
        sidx = jax.lax.axis_index(axis)
        # xs is replicated: (n_micro, B, ...) on every stage
        state = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            state, outs = carry
            inject = xs[jnp.minimum(t, n_micro - 1)]
            x_in = jnp.where(sidx == 0, inject, state)
            y = stage_fn(params, x_in)
            # stash finished microbatch (only meaningful on the last stage)
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (sidx == n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(valid, y, outs[jnp.maximum(out_idx, 0)]),
                jnp.maximum(out_idx, 0), 0)
            state = jax.lax.ppermute(y, axis, fwd) if fwd else y
            return state, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (state, outs))
        return outs[None]          # stacked over stages; caller takes row -1

    spec_p = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_p, P(None)),
                   out_specs=P(axis), check_rep=False)
    out = fn(stage_params, x_micro)
    # out: (n_stages, n_micro, ...) — the last stage's row holds the results
    return out.reshape((n_stages, n_micro) + x_micro.shape[1:])[-1]
