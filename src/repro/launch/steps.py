"""Step builders for the dry-run and real launches: given (arch, shape, mesh)
produce the jitted step with full in/out shardings plus ShapeDtypeStruct
input templates (``input_specs`` — no device allocation anywhere).

Cell kinds (DESIGN §5 regime mapping):
  train    QAT train_step (W3A8 fake-quant, frozen per-layer deltas in state,
           AdamW, microbatched, remat, FSDP for >=8B params)
  prefill  serve forward with int8-level weights ("q" form — 1 B/wt stream)
  decode   one-token serve step with container-packed weights ("qp" form —
           the paper's 0.4 B/wt BRAM image)

``quant='float'`` switches any cell to the bf16 GPU-like baseline for
before/after comparisons.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import optim as optim_lib
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8, QuantPolicy
from repro.distributed import sharding as shd
from repro.distributed.context import cost_exact_mode, sharding_rules
from repro.models import get_model, init_cache
from repro.models.frontends import frontend_embed_shape, text_len
from repro.training.loop import make_train_step

__all__ = ["build_cell", "input_specs", "CellSpec", "FSDP_THRESHOLD"]

FSDP_THRESHOLD = 6e9       # params; above this fp32 master+Adam needs ZeRO-3
PARAM_DTYPE = jnp.float32  # master weights
COMPUTE_DTYPE = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    if shape.kind == "decode":
        return {"tokens": _sds((b, 1), jnp.int32)}
    st = text_len(cfg, shape.seq_len)
    out = {"tokens": _sds((b, st), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = _sds((b, st), jnp.int32)
    if cfg.frontend is not None:
        out["frontend_embeds"] = _sds(frontend_embed_shape(cfg, b),
                                      COMPUTE_DTYPE)
    return out


@dataclasses.dataclass
class CellSpec:
    """Everything the dry-run needs for one (arch x shape x mesh) cell."""
    fn: Any                  # the function to jit (already wrapped)
    args: Tuple[Any, ...]    # ShapeDtypeStruct pytrees
    in_shardings: Any
    out_shardings: Any
    donate: Tuple[int, ...] = ()


def _policy(quant: str) -> QuantPolicy:
    return FLOAT if quant == "float" else W3A8


# --- templates (eval_shape only — never allocates) -------------------------------

def _params_template(cfg: ModelConfig, quant: str, kind: str):
    mod = get_model(cfg)

    def make(key):
        p = mod.init(key, cfg, dtype=PARAM_DTYPE)
        if kind == "train" or quant == "float":
            return p
        pol = _policy(quant)
        if kind == "prefill" or quant == "w3levels":
            return quant_dense.export_levels(p, pol)
        return quant_dense.export_container(p, pol)

    return jax.eval_shape(make, jax.random.PRNGKey(0))


def _state_template(cfg: ModelConfig, tcfg: TrainConfig, quant: str):
    params = _params_template(cfg, quant, "train")
    opt = optim_lib.make(tcfg.optimizer)

    def make(p):
        st = {"params": p, "opt": opt.init(p),
              "step": jnp.zeros((), jnp.int32)}
        if quant != "float":
            st["deltas"] = quant_dense.fit_deltas_stacked(p, _policy(quant))
        return st

    return jax.eval_shape(make, params)


def _cache_template(cfg: ModelConfig, shape: ShapeConfig,
                    kv8: bool = False):
    if kv8:
        if cfg.family == "ssm":
            # no KV cache to quantize — say so instead of silently
            # building the float state cache under a kv8-labelled cell
            import warnings
            warnings.warn(
                f"kv8 requested for family 'ssm' ({cfg.name}): it has no "
                "KV cache; building the float state cache", stacklevel=2)
        else:      # transformer family AND hybrid both serve int8 KV now
            return jax.eval_shape(
                lambda: get_model(cfg).init_cache(cfg, shape.global_batch,
                                                  shape.seq_len,
                                                  COMPUTE_DTYPE,
                                                  quantized=True))
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           COMPUTE_DTYPE))


# --- cell builders ------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               quant: str = "w3", tcfg: Optional[TrainConfig] = None,
               attn_chunk: int = 1024, num_layers_override: Optional[int] = None,
               cost_exact: bool = False, fsdp: Optional[bool] = None,
               ssd_chunk: int = 0, kv8: bool = False) -> CellSpec:
    if num_layers_override is not None:
        kw = {"num_layers": num_layers_override}
        if cfg.attn_every:
            kw["attn_every"] = min(cfg.attn_every, max(num_layers_override, 1)) \
                if num_layers_override else cfg.attn_every
        cfg = dataclasses.replace(cfg, **kw)
    if shape.kind == "train":
        cell = _build_train(cfg, shape, mesh, quant, tcfg, attn_chunk, fsdp,
                            ssd_chunk)
    elif shape.kind == "prefill":
        cell = _build_prefill(cfg, shape, mesh, quant, attn_chunk)
    else:
        cell = _build_decode(cfg, shape, mesh, quant, kv8)
    if cost_exact:
        # trace under cost-exact mode: inner chunk loops unroll so XLA's
        # body-counted-once cost analysis sees every iteration (dryrun aux)
        inner = cell.fn

        def exact_fn(*args):
            with cost_exact_mode():
                return inner(*args)

        cell = dataclasses.replace(cell, fn=exact_fn) if dataclasses.is_dataclass(cell) else cell
        cell.fn = exact_fn
    return cell


def _rules_ctx(cfg, shape, mesh):
    table = shd.activation_rules(cfg, shape, mesh)
    table["__mesh__"] = mesh
    return table


def _build_train(cfg, shape, mesh, quant, tcfg, attn_chunk,
                 fsdp: Optional[bool] = None, ssd_chunk: int = 0) -> CellSpec:
    tcfg = tcfg or TrainConfig(
        microbatches=_default_microbatches(cfg, shape, mesh))
    policy = _policy(quant)
    if fsdp is None:
        fsdp = cfg.param_count() >= FSDP_THRESHOLD
    state_t = _state_template(cfg, tcfg, quant)
    batch_t = input_specs(cfg, shape)
    state_specs = shd.state_specs(cfg, state_t, mesh, fsdp=fsdp)
    batch_specs = shd.batch_specs(cfg, shape, mesh, batch_t)
    rules = _rules_ctx(cfg, shape, mesh)

    mkw = {"attn_chunk": attn_chunk}
    if cfg.family in ("ssm", "hybrid") and ssd_chunk:
        mkw["chunk"] = ssd_chunk
    step_fn, _ = make_train_step(cfg, tcfg, policy, dtype=COMPUTE_DTYPE,
                                 model_kwargs=mkw)

    def wrapped(state, batch):
        with sharding_rules(rules):
            new_state, metrics = step_fn(state, batch)
        return new_state, metrics

    metric_specs = {k: P() for k in
                    ("loss", "aux", "acc", "gnorm", "lr")}
    return CellSpec(
        fn=wrapped,
        args=(state_t, batch_t),
        in_shardings=(shd.tree_shardings(mesh, state_specs),
                      shd.tree_shardings(mesh, batch_specs)),
        out_shardings=(shd.tree_shardings(mesh, state_specs),
                       shd.tree_shardings(mesh, metric_specs)),
        donate=(0,),
    )


def _default_microbatches(cfg, shape, mesh) -> int:
    """Keep per-device microbatch activation footprint ~<1GB."""
    dp = shd.axis_size(mesh, shd.dp_axes(mesh))
    per_dev_batch = max(shape.global_batch // dp, 1)
    act_bytes = per_dev_batch * shape.seq_len * cfg.d_model * 2
    micro = 1
    while act_bytes / micro > (1 << 30) and micro < per_dev_batch:
        micro *= 2
    return micro


def _build_prefill(cfg, shape, mesh, quant, attn_chunk) -> CellSpec:
    policy = _policy(quant)
    params_t = _params_template(cfg, quant, "prefill")
    batch_t = input_specs(cfg, shape)
    pspecs = shd.param_specs(cfg, params_t, mesh)
    bspecs = shd.batch_specs(cfg, shape, mesh, batch_t)
    cache_t = jax.eval_shape(
        lambda p, b: get_model(cfg).prefill(
            p, b, cfg, policy=policy, dtype=COMPUTE_DTYPE,
            attn_chunk=attn_chunk, max_len=shape.seq_len)[1],
        params_t, batch_t)
    cspecs = shd.cache_specs(cfg, shape, mesh, cache_t)
    rules = _rules_ctx(cfg, shape, mesh)
    mod = get_model(cfg)

    def serve_prefill(params, batch):
        with sharding_rules(rules):
            logits, cache = mod.prefill(params, batch, cfg, policy=policy,
                                        dtype=COMPUTE_DTYPE,
                                        attn_chunk=attn_chunk,
                                        max_len=shape.seq_len)
        return logits, cache

    logits_spec = shd.activation_rules(cfg, shape, mesh)["logits"]
    return CellSpec(
        fn=serve_prefill,
        args=(params_t, batch_t),
        in_shardings=(shd.tree_shardings(mesh, pspecs),
                      shd.tree_shardings(mesh, bspecs)),
        out_shardings=(shd.tree_shardings(mesh, logits_spec),
                       shd.tree_shardings(mesh, cspecs)),
    )


def _build_decode(cfg, shape, mesh, quant, kv8: bool = False) -> CellSpec:
    policy = _policy(quant)
    params_t = _params_template(cfg, quant, "decode")
    batch_t = input_specs(cfg, shape)
    cache_t = _cache_template(cfg, shape, kv8=kv8)
    pspecs = shd.param_specs(cfg, params_t, mesh)
    bspecs = shd.batch_specs(cfg, shape, mesh, batch_t)
    cspecs = shd.cache_specs(cfg, shape, mesh, cache_t)
    rules = _rules_ctx(cfg, shape, mesh)
    mod = get_model(cfg)

    def serve_decode(params, cache, batch):
        with sharding_rules(rules):
            logits, cache = mod.decode_step(params, cache, batch["tokens"],
                                            cfg, policy=policy,
                                            dtype=COMPUTE_DTYPE)
        return logits, cache

    logits_spec = shd.activation_rules(cfg, shape, mesh)["logits"]
    return CellSpec(
        fn=serve_decode,
        args=(params_t, cache_t, batch_t),
        in_shardings=(shd.tree_shardings(mesh, pspecs),
                      shd.tree_shardings(mesh, cspecs),
                      shd.tree_shardings(mesh, bspecs)),
        out_shardings=(shd.tree_shardings(mesh, logits_spec),
                       shd.tree_shardings(mesh, cspecs)),
        donate=(1,),
    )
