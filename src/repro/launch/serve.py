"""Serving launcher: quantize-and-serve any assigned arch through the batched
continuous-batching engine (one jitted decode per tick, all slots at once).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 8 --slots 4 --max-new 16

Speculative serving (the 3-bit drafter proposes, the serving form verifies):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --quant float --spec-k 4 --requests 8 --slots 4 --max-new 16

Overload-hardened serving (bounded admission + deadlines + preemption +
watchdog; prints the resilience counters after the run):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 16 --slots 2 --queue-limit 8 --shed-policy drop_oldest \
        --deadline 48 --preempt 8 --max-ticks 512

Durable serving (periodic snapshots + write-ahead journal + weight-store
integrity probe; ``--resume`` recovers a killed run from the latest
snapshot plus the journal tail before serving):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 8 --slots 4 --snapshot-dir /tmp/snaps --snapshot-every 16 \
        --journal /tmp/serve.jsonl --integrity-every 32 [--resume]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.serving.engine import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="w3", choices=["float", "w3"])
    ap.add_argument("--form", default="qp", choices=["w", "q", "qp"],
                    help="weight form for --quant w3: levels (q) or packed "
                         "containers (qp, the paper's BRAM image)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--matmul-mode", default="auto",
                    choices=["auto", "kernel", "dequant"],
                    help="quantized-matmul dispatch: Pallas kernels, fused "
                         "dequant fallback, or auto (kernel on TPU)")
    ap.add_argument("--attn-mode", default="auto",
                    choices=["auto", "kernel", "ref"],
                    help="attention dispatch for prefill admission, "
                         "speculative verify AND per-token decode: Pallas "
                         "kernels (blocked prefill/verify + fused decode), "
                         "einsum/chunked reference, or auto (kernel on TPU)")
    ap.add_argument("--kv8", action="store_true",
                    help="serve from an int8 KV cache (per-token scales; "
                         "half the cache bytes per slot — attention "
                         "families only)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: the packed-3-bit drafter "
                         "(api.draft_of of the same checkpoint) proposes "
                         "K tokens per tick, the serving weights verify "
                         "them in one multi-token pass (dense/moe/hybrid; "
                         "ssm rejects)")
    ap.add_argument("--draft-depth", type=float, default=1.0,
                    help="fraction of the layer stack the drafter keeps "
                         "(1.0 = full-depth self-draft)")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bounded admission: queued requests past this "
                         "depth are shed per --shed-policy")
    ap.add_argument("--shed-policy", default="reject",
                    choices=["reject", "drop_oldest"],
                    help="what bounded admission sheds when the queue is "
                         "full: the new request, or the oldest queued one")
    ap.add_argument("--deadline", type=int, default=None,
                    help="default per-request deadline in decode ticks; "
                         "expired requests are cancelled mid-stream "
                         "(partial output, status='deadline')")
    ap.add_argument("--preempt", type=int, default=None,
                    help="preempt a slot held this many ticks when the "
                         "queue has waiters; the request requeues with its "
                         "committed tokens (token-exact at T=0)")
    ap.add_argument("--max-ticks", type=int, default=None,
                    help="watchdog: abort run_all with a diagnostic dump "
                         "after this many driver iterations")
    ap.add_argument("--snapshot-dir", default=None,
                    help="durability: persist atomic engine snapshots here "
                         "(device caches + host bookkeeping + RNG key)")
    ap.add_argument("--snapshot-every", type=int, default=None,
                    help="snapshot every N decode ticks (needs "
                         "--snapshot-dir)")
    ap.add_argument("--journal", default=None,
                    help="write-ahead JSONL journal of submit/admit/commit/"
                         "finish/shed events (the replay tail for --resume)")
    ap.add_argument("--resume", action="store_true",
                    help="recover before serving: restore the latest "
                         "snapshot under --snapshot-dir and resubmit the "
                         "journal tail (then ALSO submit this run's "
                         "requests)")
    ap.add_argument("--integrity-every", type=int, default=None,
                    help="run the weight-store canary fingerprint probe "
                         "every N ticks; detected corruption is healed "
                         "from the golden copy")
    ap.add_argument("--golden-dir", default=None,
                    help="also persist the golden weight copy + CRC "
                         "manifest here (checkpoint.integrity)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    draft_cfg = draft_params = None
    if args.spec_k:
        # derive the drafter from the master float weights BEFORE the
        # serving form is exported (draft_of re-exports its slice to qp)
        from repro.models import api as model_api
        draft_cfg, draft_params = model_api.draft_of(
            cfg, params, depth_fraction=args.draft_depth)
    if args.quant == "w3":
        export = {"q": quant_dense.export_levels,
                  "qp": quant_dense.export_container}.get(args.form)
        if export:
            params = export(params, W3A8)
        policy = W3A8
    else:
        policy = FLOAT

    eng = ServingEngine(params, cfg, policy=policy, slots=args.slots,
                        max_len=64 + args.max_new + args.spec_k,
                        temperature=args.temperature, eos_id=args.eos_id,
                        matmul_mode=args.matmul_mode,
                        attn_mode=args.attn_mode,
                        kv_bits=8 if args.kv8 else None,
                        spec_k=args.spec_k, draft_params=draft_params,
                        draft_cfg=draft_cfg,
                        queue_limit=args.queue_limit,
                        shed_policy=args.shed_policy,
                        default_deadline=args.deadline,
                        preempt_after=args.preempt,
                        max_ticks=args.max_ticks,
                        snapshot_dir=args.snapshot_dir,
                        snapshot_every=args.snapshot_every,
                        journal=args.journal,
                        integrity_every=args.integrity_every,
                        golden_dir=args.golden_dir)
    if args.resume:
        stats = eng.recover()
        print(f"recovered: snapshot step {stats['restored_step']}, "
              f"{stats['replayed_events']} journal events replayed, "
              f"{stats['resubmitted']} requests resubmitted")
    # mixed prompt lengths: exercises the length-bucketed batched admission
    lens = [4, 8, 5, 12, 3, 16, 7, 9]
    t0 = time.time()
    for i in range(args.requests):
        plen = lens[i % len(lens)]
        eng.submit([(1 + i + j) % 50 + 1 for j in range(plen)],
                   max_new=args.max_new)
    done = eng.run_all()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    spec = (f", spec accept rate {eng.spec_accept_rate:.2f} "
            f"(K={args.spec_k})" if args.spec_k else "")
    print(f"{len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s on CPU), "
          f"{eng.decode_calls} batched decode ticks "
          f"({toks / max(eng.decode_calls, 1):.2f} tok/tick), "
          f"{eng.prefill_calls} bucketed prefill calls "
          f"({len(done) / max(eng.prefill_calls, 1):.2f} req/prefill)"
          f"{spec}")
    if (args.queue_limit is not None or args.deadline is not None
            or args.preempt is not None):
        by_status: dict = {}
        for r in done:
            by_status[r.status] = by_status.get(r.status, 0) + 1
        print(f"resilience: statuses {by_status}, "
              f"shed {eng.shed_count}, "
              f"deadline misses {eng.deadline_miss_count}, "
              f"preemptions {eng.preempt_count}, "
              f"poisoned {eng.poisoned_count}, "
              f"queue peak {eng.queue_peak}")
    if (args.snapshot_dir is not None or args.journal is not None
            or args.integrity_every is not None):
        print(f"durability: snapshots written {eng.snapshots_written}, "
              f"journal events {eng.journal_events}, "
              f"replayed {eng.replayed_events}, "
              f"integrity probes {eng.integrity_probes}, "
              f"heals {eng.heal_count}")


if __name__ == "__main__":
    main()
