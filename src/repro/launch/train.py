"""Training launcher: any assigned arch on any mesh.

On real hardware this is the per-host entry point (jax.distributed
initialization + the production mesh); in this container it runs reduced
configs on the host mesh with the same code path.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
        --steps 50 --quant w3a8 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.configs import TrainConfig, get_config, reduced
from repro.core.precision import FLOAT, W3A8
from repro.data.pipeline import HostLoader
from repro.data.synthetic import lm_batch
from repro.distributed.context import sharding_rules
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import get_model
from repro.training.loop import Trainer, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--quant", default="w3a8", choices=["float", "w3a8"])
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config (same family structure)")
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    policy = W3A8 if args.quant == "w3a8" else FLOAT
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1))
    mesh = (make_host_mesh() if args.mesh == "host" else
            make_production_mesh(multi_pod=args.mesh == "multi"))

    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    step_fn, init_state = make_train_step(cfg, tcfg, policy)
    state = init_state(params)

    start_step = 0
    ck = None
    if args.ckpt_dir:
        ck = ckpt_lib.Checkpointer(args.ckpt_dir, keep=3)
        if args.resume and ckpt_lib.latest_step(args.ckpt_dir) is not None:
            # elastic restore: re-shard onto the current mesh
            specs = shd.state_specs(cfg, state, mesh)
            shardings = shd.tree_shardings(mesh, specs)
            tree, meta = ckpt_lib.restore(args.ckpt_dir, shardings=shardings)
            state = jax.tree_util.tree_map(jnp.asarray, tree)
            start_step = meta["step"]
            print(f"resumed from step {start_step}")

    rules = shd.activation_rules(
        cfg, type("S", (), {"global_batch": args.batch})(), mesh) \
        if args.mesh != "host" else {}
    step_fn = jax.jit(step_fn, donate_argnums=0)
    loader = HostLoader(lambda seed, s: lm_batch(
        jnp.asarray(seed), jnp.asarray(s), batch=args.batch, seq=args.seq,
        vocab=cfg.vocab_size), start_step=start_step)

    with mesh:
        with sharding_rules(rules):
            trainer = Trainer(step_fn, state, checkpointer=ck,
                              ckpt_every=max(args.steps // 5, 10))
            trainer.run(loader, args.steps,
                        on_log=lambda r: print(
                            f"step {r['step']:5d} loss {r['loss']:.4f} "
                            f"lr {r['lr']:.2e} {r['dt'] * 1e3:.0f}ms"))
    print(f"done; stragglers {trainer.monitor.slow_steps}/"
          f"{trainer.monitor.total_steps}")


if __name__ == "__main__":
    main()
