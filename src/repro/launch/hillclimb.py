import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^^ before any jax import (same contract as dryrun.py).

"""Perf hillclimbing (§Perf): lower a cell under a named VARIANT of the
build knobs, reconstruct exact roofline terms (same L0/L1 methodology as the
dry-run), and append hypothesis→change→before→after→verdict records to
results/perf_log.json.

    PYTHONPATH=src:. python -m repro.launch.hillclimb --cell qwen3-32b:decode_32k \
        --variant quant=float --hypothesis "..." --baseline
"""
import argparse
import json
import time

import jax

from benchmarks import roofline as rl
from repro.configs import TrainConfig, get_config, shape_by_name
from repro.analysis import hlo as hlo_analysis
from repro.launch.dryrun import aux_overrides
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

LOG = "results/perf_log.json"


def lower_variant(arch: str, shape_name: str, knobs: dict, mesh=None):
    """Full + aux lowerings under knobs; returns a dry-run-style record."""
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh = mesh or make_production_mesh()
    rec = {"arch": arch, "shape": shape_name, "mesh": "single",
           "quant": knobs.get("quant", "w3"),
           "num_layers": cfg.num_layers, "attn_every": cfg.attn_every,
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count(),
           "seq_len": shape.seq_len, "global_batch": shape.global_batch,
           "kind": shape.kind, "status": "ok", "knobs": knobs}

    def one(layers_override=None):
        tcfg = None
        if shape.kind == "train":
            micro = knobs.get("microbatches")
            tcfg = TrainConfig(
                microbatches=1 if layers_override is not None else (micro or 1),
                remat=knobs.get("remat", "layer"))
            if micro and layers_override is None:
                tcfg = TrainConfig(microbatches=micro,
                                   remat=knobs.get("remat", "layer"))
        t0 = time.time()
        with mesh:
            cell = build_cell(
                cfg, shape, mesh,
                quant=knobs.get("quant", "w3"),
                attn_chunk=knobs.get("attn_chunk", 1024),
                fsdp=knobs.get("fsdp"),
                ssd_chunk=knobs.get("ssd_chunk", 0),
                kv8=bool(knobs.get("kv8", False)),
                tcfg=tcfg,
                num_layers_override=layers_override,
                cost_exact=layers_override is not None)
            jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
            compiled = jf.lower(*cell.args).compile()
        return {"cost": hlo_analysis.cost_summary(compiled),
                "memory": hlo_analysis.memory_summary(compiled),
                "collectives": hlo_analysis.collective_bytes(compiled.as_text()),
                "compile_s": round(time.time() - t0, 1)}

    rec["full"] = one()
    for name, ov in aux_overrides(cfg).items():
        rec[name] = one(ov)
    return rec


def measure(arch, shape_name, knobs):
    rec = lower_variant(arch, shape_name, knobs)
    terms = rl.analyze_cell(rec)
    return rec, terms


def append_log(cell_key: str, entry: dict):
    log = json.load(open(LOG)) if os.path.exists(LOG) else {}
    log.setdefault(cell_key, []).append(entry)
    os.makedirs("results", exist_ok=True)
    json.dump(log, open(LOG, "w"), indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variant", default="", help="k=v,k=v knobs")
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--change", default="")
    args = ap.parse_args()
    arch, shape_name = args.cell.split(":")
    knobs = {}
    for kv in filter(None, args.variant.split(",")):
        k, v = kv.split("=")
        knobs[k] = (v if k == "quant" else
                    v == "true" if v in ("true", "false") else int(v))
    rec, terms = measure(arch, shape_name, knobs)
    print(json.dumps(terms, indent=2))


if __name__ == "__main__":
    main()
