"""Compat shim: this module moved to ``repro.analysis.hlo``.

The HLO-text analysis now lives under the static-analysis subsystem as its
compiled-artifact backend; import from ``repro.analysis.hlo`` in new code.
"""
from repro.analysis.hlo import (  # noqa: F401
    DTYPE_BYTES,
    KINDS,
    _shape_bytes,
    collective_bytes,
    cost_summary,
    memory_summary,
)

__all__ = ["collective_bytes", "DTYPE_BYTES", "cost_summary",
           "memory_summary", "KINDS", "_shape_bytes"]
