"""Production mesh definition (MULTI-POD DRY-RUN spec, step 1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. Single pod: 16x16 = 256 chips (v5e pod slice), axes
(data, model). Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model); the
``pod`` axis carries pure data parallelism (slow inter-pod links see only
gradient all-reduce, overlapped with backward — DESIGN §8).
"""
from __future__ import annotations

import jax

__all__ = ["compat_make_mesh", "make_production_mesh", "make_host_mesh"]


def compat_make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist in jax >= 0.5;
    on those versions we pin every axis to ``Auto`` — the pre-0.5 default —
    so mesh semantics are identical either way.
    """
    kw = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kw["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return compat_make_mesh((data, model), ("data", "model"))
