"""Production mesh definition (MULTI-POD DRY-RUN spec, step 1).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. Single pod: 16x16 = 256 chips (v5e pod slice), axes
(data, model). Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model); the
``pod`` axis carries pure data parallelism (slow inter-pod links see only
gradient all-reduce, overlapped with backward — DESIGN §8).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
