import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run (deliverable e): for every (architecture x input-shape x
mesh), jit the real train/serve step with full shardings, ``.lower()``,
``.compile()``, and record memory_analysis / cost_analysis / collective bytes
into results/dryrun/*.json. Single-pod cells additionally lower the L0/L1
(hybrid: L0/G1/A1) reduced-depth variants that the roofline assembly uses to
undo XLA's body-counted-once while-loop cost accounting.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, LM_SHAPES, get_config, shape_by_name
from repro.analysis import hlo as hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, input_specs  # noqa: F401 (public API)

OUT_DIR = "results/dryrun"


def cell_id(arch, shape, mesh_name, quant):
    return f"{arch}__{shape}__{mesh_name}__{quant}"


def runnable_shapes(cfg):
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue   # skip documented in DESIGN §6 / EXPERIMENTS §Dry-run
        out.append(s)
    return out


def lower_one(cfg, shape, mesh, quant, layers_override=None, tcfg=None):
    t0 = time.time()
    with mesh:
        cell = build_cell(cfg, shape, mesh, quant=quant,
                          num_layers_override=layers_override, tcfg=tcfg,
                          cost_exact=layers_override is not None)
        jf = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate)
        lowered = jf.lower(*cell.args)
        compiled = lowered.compile()
    rec = {
        "cost": hlo_analysis.cost_summary(compiled),
        "memory": hlo_analysis.memory_summary(compiled),
        "collectives": hlo_analysis.collective_bytes(compiled.as_text()),
        "compile_s": round(time.time() - t0, 1),
    }
    del compiled, lowered
    return rec


def aux_overrides(cfg):
    """Reduced-depth lowerings for roofline cost reconstruction."""
    if cfg.family == "hybrid":
        return {"L0": 0, "G1": cfg.attn_every, "A1": 1}
    return {"L0": 0, "L1": 1}


def prefill_seq_samples(cfg):
    """Cost-exact unrolling at 32k is compile-prohibitive for chunked inner
    loops; every cost term is polynomial (<=2) in S, so three samples pin the
    exact quadratic, evaluated at the true S (benchmarks.roofline).
    SWA archs sample above 2x window to stay in the linear windowed regime."""
    if cfg.sliding_window:
        w = cfg.sliding_window
        return [2 * w, 3 * w, 4 * w]
    return [1024, 2048, 4096]


def run_cell(arch, shape_name, mesh_name, quant, *, force=False,
             with_aux=True):
    os.makedirs(OUT_DIR, exist_ok=True)
    cid = cell_id(arch, shape_name, mesh_name, quant)
    path = os.path.join(OUT_DIR, cid + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip] {cid} (cached)")
        return json.load(open(path))
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    print(f"[run ] {cid} ...", flush=True)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "quant": quant, "num_layers": cfg.num_layers,
           "attn_every": cfg.attn_every,
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count(),
           "seq_len": shape.seq_len, "global_batch": shape.global_batch,
           "kind": shape.kind}
    try:
        rec["full"] = lower_one(cfg, shape, mesh, quant)
        if with_aux and mesh_name == "single":
            import dataclasses as dc

            from repro.configs.base import TrainConfig
            aux_tcfg = TrainConfig(microbatches=1) if shape.kind == "train" else None
            if shape.kind == "prefill":
                rec["aux_scheme"] = "seqfit"
                samples = prefill_seq_samples(cfg)
                rec["seq_samples"] = samples
                for s in samples:
                    sshape = dc.replace(shape, seq_len=s)
                    for name, ov in aux_overrides(cfg).items():
                        rec[f"{name}@{s}"] = lower_one(
                            cfg, sshape, mesh, quant, layers_override=ov)
            else:
                rec["aux_scheme"] = "exact"
                for name, ov in aux_overrides(cfg).items():
                    rec[name] = lower_one(cfg, shape, mesh, quant,
                                          layers_override=ov, tcfg=aux_tcfg)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(rec["traceback"])
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=2)
    os.replace(tmp, path)
    print(f"[{'ok' if rec['status'] == 'ok' else 'ERR '}] {cid} "
          f"({rec.get('full', {}).get('compile_s', '?')}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="w3")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-aux", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    # smallest-first banking: cheap archs compile first
    archs.sort(key=lambda a: get_config(a).param_count())
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else \
            [s.name for s in runnable_shapes(cfg)]
        for sname in shapes:
            for mname in meshes:
                rec = run_cell(arch, sname, mname, args.quant,
                               force=args.force, with_aux=not args.no_aux)
                failures += rec["status"] != "ok"
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
