"""zamba2-1.2b [hybrid]: 38 Mamba2 layers d_model=2048 + ONE shared attention
block (32H kv=32, d_ff=8192) applied every 6th layer, vocab=32000,
ssm_state=64 [arXiv:2411.15242; hf].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1, ssm_conv=4,
    attn_every=6, tie_embeddings=True,
)
