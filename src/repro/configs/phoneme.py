"""The paper's TIMIT phoneme DNN (§2.1): 429-1022x4-61 (11 frames of MFCC),
sigmoid hidden units, 3-bit hidden weights / 8-bit output weights."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phoneme", family="mlp",
    num_layers=4, d_model=1022, vocab_size=61,
    d_ff=429, mlp_act="sigmoid",
)

INPUT_DIM = 429
HIDDEN = (1022, 1022, 1022, 1022)
NUM_CLASSES = 61
