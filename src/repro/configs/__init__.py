from repro.configs.base import (ARCH_IDS, LM_SHAPES, ModelConfig, ShapeConfig,
                                TrainConfig, get_config, reduced, shape_by_name)

__all__ = ["ARCH_IDS", "LM_SHAPES", "ModelConfig", "ShapeConfig", "TrainConfig",
           "get_config", "reduced", "shape_by_name"]
