"""Config system: model / shape / train / quant configs.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` resolves by id (e.g. "qwen3-32b").
``reduced(cfg)`` shrinks any config to a CPU-smokeable size with the same
family-specific structure (used by per-arch smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig", "get_config",
           "reduced", "LM_SHAPES", "ARCH_IDS", "shape_by_name"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    # attention (0 heads => attention-free)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0         # 0 => full attention
    rope_theta: float = 10000.0
    # ffn
    d_ff: int = 0
    mlp_act: str = "silu"           # silu (SwiGLU) | gelu | sigmoid
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_bf16: bool = False          # SSD einsum operands in bf16 (§Perf)
    ssm_split_proj: bool = False    # shard-aligned split z/x/BC/dt projections
                                    # + per-component convs (§Perf H-split)
    # hybrid (zamba2-style shared attention)
    attn_every: int = 0             # 0 => not hybrid
    # frontend stub
    frontend: Optional[str] = None  # audio | vision
    frontend_tokens: int = 256      # patches/frames provided pre-embedded
    # numerics
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d * (1 if self.tie_embeddings else 2)            # embed + head
        per_layer = 0
        if self.num_heads:
            hd = self.head_dim or d // self.num_heads
            per_layer += d * self.num_heads * hd                  # wq
            per_layer += 2 * d * self.num_kv_heads * hd           # wk, wv
            per_layer += self.num_heads * hd * d                  # wo
        if self.family in ("ssm", "hybrid"):
            di, ns = self.d_inner, self.ssm_state
            in_dim = 2 * di + 2 * self.ssm_ngroups * ns + self.ssm_heads
            per_layer_ssm = d * in_dim + di * d                   # in/out proj
            per_layer_ssm += self.ssm_conv * (di + 2 * self.ssm_ngroups * ns)
            if self.family == "ssm":
                per_layer = per_layer_ssm
            else:
                # hybrid: every layer is ssm; ONE shared attn block extra
                n += per_layer + 3 * d * ff if False else 0
                per_layer = per_layer_ssm
        if ff and self.family not in ("moe", "hybrid"):
            # hybrid layers are pure mamba blocks — only the ONE shared
            # attention block has an FFN (added below)
            nmats = 3 if self.mlp_act == "silu" else 2
            per_layer += nmats * d * ff
        if self.family == "moe":
            nmats = 3 if self.mlp_act == "silu" else 2
            per_layer += self.num_experts * nmats * d * ff
            per_layer += d * self.num_experts                     # router
        n += self.num_layers * per_layer
        if self.family == "hybrid" and self.num_heads:
            hd = self.head_dim or d // self.num_heads
            n += 2 * (d * self.num_heads * hd) + 2 * d * self.num_kv_heads * hd
            n += 3 * d * ff if ff else 0                          # shared block
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        nmats = 3 if self.mlp_act == "silu" else 2
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * nmats * d * ff
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3
    momentum: float = 0.9            # paper: SGD momentum 0.9
    optimizer: str = "adamw"         # adamw | sgd (paper)
    weight_decay: float = 0.0
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatches: int = 1            # grad accumulation
    remat: str = "layer"             # none | layer | full
    seed: int = 0


ARCH_IDS = (
    "musicgen-large", "qwen3-32b", "qwen2.5-14b", "stablelm-3b", "qwen2-1.5b",
    "phi3.5-moe-42b-a6.6b", "mixtral-8x22b", "mamba2-2.7b", "internvl2-26b",
    "zamba2-1.2b",
)

_MODULE_FOR = {
    "musicgen-large": "musicgen_large",
    "qwen3-32b": "qwen3_32b",
    "qwen2.5-14b": "qwen2_5_14b",
    "stablelm-3b": "stablelm_3b",
    "qwen2-1.5b": "qwen2_1_5b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "mixtral-8x22b": "mixtral_8x22b",
    "mamba2-2.7b": "mamba2_2_7b",
    "internvl2-26b": "internvl2_26b",
    "zamba2-1.2b": "zamba2_1_2b",
    "digit": "digit",
    "phoneme": "phoneme",
}


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
    return mod.CONFIG


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 128) -> ModelConfig:
    """Shrink to CPU-smokeable size, preserving family structure."""
    scale = d_model / cfg.d_model
    heads = max(1, min(cfg.num_heads, 4)) if cfg.num_heads else 0
    kv = max(1, min(cfg.num_kv_heads, heads)) if cfg.num_kv_heads else 0
    return dataclasses.replace(
        cfg,
        num_layers=layers,
        d_model=d_model,
        d_ff=max(16, int(cfg.d_ff * scale)) if cfg.d_ff else 0,
        vocab_size=vocab,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads if heads else 0,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        attn_every=2 if cfg.attn_every else 0,
        frontend_tokens=8 if cfg.frontend else cfg.frontend_tokens,
    )
