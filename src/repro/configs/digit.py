"""The paper's handwritten-digit DNN (§2.1): 784-1022-1022-1022-10, sigmoid
hidden units, 3-bit hidden weights / 8-bit output weights, 8-bit signals."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="digit", family="mlp",
    num_layers=3, d_model=1022, vocab_size=10,   # d_model = hidden width
    d_ff=784, mlp_act="sigmoid",                 # d_ff reused as input dim
)

INPUT_DIM = 784
HIDDEN = (1022, 1022, 1022)
NUM_CLASSES = 10
