"""mamba2-2.7b [ssm]: 64L d_model=2560 attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality) [arXiv:2405.21060; unverified].

d_inner = 2*2560 = 5120, headdim=64 => 80 SSD heads, ngroups=1, conv width 4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1, ssm_conv=4,
    tie_embeddings=True,
)
