"""musicgen-large [audio]: decoder-only LM over EnCodec tokens.

48L d_model=2048 32H (MHA: kv=32) d_ff=8192 vocab=2048 [arXiv:2306.05284; hf].
EnCodec frontend is a stub per assignment: input_specs() provides the token
stream (and optionally precomputed conditioning frames).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048, mlp_act="gelu", frontend="audio",
)
