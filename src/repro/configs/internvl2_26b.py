"""internvl2-26b [vlm]: InternViT frontend (STUB per assignment) + InternLM2-20B
backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf]. input_specs() provides precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553, frontend="vision", frontend_tokens=256,
)
