"""Losses and metrics."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["softmax_xent", "accuracy"]

IGNORE = -1


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray):
    """Mean token NLL over labels != IGNORE. logits (..., V) fp32."""
    logits = logits.astype(jnp.float32)
    valid = labels != IGNORE
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray):
    valid = labels != IGNORE
    pred = jnp.argmax(logits, axis=-1)
    return jnp.sum((pred == labels) * valid) / jnp.maximum(jnp.sum(valid), 1)
