"""Train-step builder + Trainer driver.

``make_train_step`` returns a pure jit-able function
``(state, batch) -> (state, metrics)`` implementing:

  * forward under the active QuantPolicy (float / fake-W3A8 / frozen deltas)
  * MoE aux-loss mixing
  * microbatched gradient accumulation (``lax.scan`` over microbatches —
    memory scales with ONE microbatch; mandatory at global_batch 256 x 4k)
  * global-norm clipping, LR schedule, optimizer update
  * optional gradient compression (int8 + error feedback, DESIGN §8)

``Trainer`` adds the systems side: double-buffered input, async checkpoints,
restart-from-latest, and a straggler monitor (per-step wall-time EMA;
steps > ``straggler_factor`` x EMA are counted and surfaced — on a real
cluster this feeds the controller that re-shards around slow hosts).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import optim as optim_lib
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.precision import QuantPolicy
from repro.models import get_model
from repro.training.losses import IGNORE, accuracy, softmax_xent

__all__ = ["TrainState", "make_loss_fn", "make_train_step", "Trainer"]

AUX_WEIGHT = 0.01


def TrainState(params, opt_state, step=0, extra=None) -> Dict[str, Any]:
    st = {"params": params, "opt": opt_state,
          "step": jnp.asarray(step, jnp.int32)}
    if extra:
        st.update(extra)
    return st


def make_loss_fn(cfg: ModelConfig, policy: QuantPolicy, deltas=None,
                 dtype=jnp.bfloat16, remat: str = "layer",
                 attn_chunk: int = 1024, model_kwargs: Optional[Dict] = None):
    mod = get_model(cfg)
    mkw = dict(model_kwargs or {})
    mkw.setdefault("attn_chunk", attn_chunk)

    def loss_fn(params, batch, deltas=deltas):
        logits, aux = mod.forward(params, batch, cfg, policy=policy,
                                  deltas=deltas, dtype=dtype, remat=remat,
                                  **mkw)
        labels = batch["labels"]
        if cfg.frontend is not None:
            pad = jnp.full(labels.shape[:1] + (cfg.frontend_tokens,), IGNORE,
                           labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        loss = softmax_xent(logits, labels)
        metrics = {"loss": loss, "aux": aux, "acc": accuracy(logits, labels)}
        return loss + AUX_WEIGHT * aux, metrics

    return loss_fn


def _split_micro(batch, n: int):
    return jax.tree_util.tree_map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, policy: QuantPolicy,
                    *, deltas=None, dtype=jnp.bfloat16,
                    grad_transform: Optional[Callable] = None,
                    donate: bool = True, model_kwargs: Optional[Dict] = None):
    """Returns (train_step, init_state_fn)."""
    opt = optim_lib.make(tcfg.optimizer, momentum=tcfg.momentum,
                         weight_decay=tcfg.weight_decay)
    sched = optim_lib.warmup_cosine(tcfg.learning_rate, tcfg.warmup_steps,
                                    tcfg.total_steps)
    loss_fn = make_loss_fn(cfg, policy, deltas, dtype, tcfg.remat,
                           model_kwargs=model_kwargs)

    def init_state(params, extra=None):
        return TrainState(params, opt.init(params), extra=extra)

    def train_step(state, batch):
        params = state["params"]
        dlt = state.get("deltas")   # frozen step sizes (paper step-2 output)

        if tcfg.microbatches > 1:
            micro = _split_micro(batch, tcfg.microbatches)

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb, dlt)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                m_acc = jax.tree_util.tree_map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"loss": jnp.zeros((), jnp.float32),
                       "aux": jnp.zeros((), jnp.float32),
                       "acc": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_body, (zeros_g, zeros_m), micro)
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.microbatches, grads)
            metrics = jax.tree_util.tree_map(
                lambda m: m / tcfg.microbatches, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, dlt)

        if grad_transform is not None:
            grads, state = grad_transform(grads, state)
        grads, gnorm = optim_lib.clip_by_global_norm(grads, tcfg.grad_clip)
        lr = sched(state["step"])
        updates, opt_state = opt.update(grads, state["opt"], params, lr)
        params = optim_lib.apply_updates(params, updates)
        new_state = dict(state)
        new_state.update(params=params, opt=opt_state, step=state["step"] + 1)
        metrics = dict(metrics, gnorm=gnorm, lr=lr)
        return new_state, metrics

    return train_step, init_state


@dataclasses.dataclass
class StragglerMonitor:
    """Wall-time EMA; counts steps slower than factor x EMA."""
    factor: float = 2.0
    ema: float = 0.0
    beta: float = 0.9
    slow_steps: int = 0
    total_steps: int = 0

    def record(self, dt: float) -> bool:
        self.total_steps += 1
        slow = self.ema > 0 and dt > self.factor * self.ema
        if slow:
            self.slow_steps += 1
            # don't pollute the EMA with the straggler itself
        else:
            self.ema = dt if self.ema == 0 else \
                self.beta * self.ema + (1 - self.beta) * dt
        return slow


class Trainer:
    """Drives train_step over a loader with checkpoint/restart."""

    def __init__(self, train_step, state, *, checkpointer=None,
                 ckpt_every: int = 0, log_every: int = 10,
                 straggler_factor: float = 2.0):
        self.train_step = train_step
        self.state = state
        self.checkpointer = checkpointer
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.monitor = StragglerMonitor(factor=straggler_factor)
        self.history = []

    def run(self, loader, num_steps: int, *, on_log=None):
        it = iter(loader)
        for i in range(num_steps):
            batch = next(it)
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.record(dt)
            step = int(self.state["step"])
            if self.log_every and (i % self.log_every == 0 or i == num_steps - 1):
                row = {k: float(v) for k, v in metrics.items()}
                row.update(step=step, dt=dt)
                self.history.append(row)
                if on_log:
                    on_log(row)
            if self.checkpointer and self.ckpt_every and step % self.ckpt_every == 0:
                self.checkpointer.save_async(step, self.state)
        if self.checkpointer:
            self.checkpointer.wait()
        return self.state
