"""Optimizers (pure-JAX, no optax in the container): SGD+momentum — the
paper's trainer (§2.1: lr 0.1/0.05, momentum 0.9) — and AdamW for the LM zoo.
Plus LR schedules and global-norm clipping.

API: ``opt = make(name, **hp); state = opt.init(params);
updates, state = opt.update(grads, state, params, lr)`` — updates are
*subtracted* by the caller (see training.loop.apply_updates).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["make", "sgd", "adamw", "cosine_schedule", "constant_schedule",
           "warmup_cosine", "clip_by_global_norm", "global_norm", "apply_updates"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]          # (grads, state, params, lr) -> (updates, state)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def sgd(momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    """The paper's optimizer: SGD with momentum 0.9."""

    def init(params):
        return {"mu": jax.tree_util.tree_map(jnp.zeros_like, params)}

    def update(grads, state, params, lr):
        mu = jax.tree_util.tree_map(lambda m, g: momentum * m + g,
                                    state["mu"], grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: lr * (momentum * m + g),
                                         mu, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: lr * m, mu)
        return upd, {"mu": mu}

    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, z),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (lr * u).astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


def make(name: str, *, momentum: float = 0.9, weight_decay: float = 0.0,
         **kw) -> Optimizer:
    if name == "sgd":
        return sgd(momentum=momentum)
    if name == "adamw":
        return adamw(weight_decay=weight_decay, **kw)
    raise ValueError(f"unknown optimizer {name}")


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p - u).astype(p.dtype),
                                  params, updates)


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, lr * w, cos(step - warmup))
    return fn
