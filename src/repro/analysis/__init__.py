"""Serve-graph contract linter: static analysis over jaxprs + Pallas specs.

The paper's whole thesis is a memory contract — the working set must fit in
fast on-chip memory, which is why only 3-bit weights are used. This package
makes the repo's equivalents of that contract machine-checked for every
serving graph, WITHOUT executing any of them:

  no_dequant            no full-shape float weight tensor materialized from
                        a quantized serve form ({"q"}/{"qp"}) outside the
                        Pallas kernels' VMEM tiles
  no_quadratic_scores   no (T, S)-shaped float score tensor in kernel-mode
                        prefill/verify graphs (the flash contract)
  no_host_callback      jitted tick graphs carry no pure_callback /
                        debug_callback / device_put — nothing that syncs or
                        transfers per token
  carry_dtype           every carried buffer (the jitted tick's cache, and
                        every scan/while carry inside it) keeps a fixed
                        dtype across iterations — the PR 5 ``block_decode``
                        bf16 drift class, caught statically
  donation              cache buffers declared donated actually alias an
                        output (no silent copy-fallback warning path)
  vmem_budget           per-kernel VMEM footprint estimated from each
                        ``pallas_call``'s BlockSpecs/grid stays under a
                        byte budget — the on-chip-memory contract itself

Layers:

  jaxpr_utils   shared jaxpr walkers (the one copy of the float-shape /
                primitive scanners the test suite used to triplicate)
  passes        the six checks, each a pure function -> list[Violation]
  vmem          pallas_call -> VMEM footprint estimation
  contracts     the contract-point registry (decode tick, bucketed prefill,
                spec tick, generate loop) + the family x form x mode sweep
  hlo           post-SPMD HLO text analysis (collective bytes, cost /
                memory summaries) — the compiled-artifact backend, formerly
                ``repro.launch.hlo_analysis``

Run the sweep: ``python -m repro.analysis --check`` (JSON report; CI gate).
"""
from repro.analysis import hlo  # noqa: F401  (the HLO-level backend)
from repro.analysis.passes import (  # noqa: F401
    Violation,
    check_carry_fixed_point,
    check_donation,
    check_no_dequant,
    check_no_host_callback,
    check_no_quadratic_scores,
    check_scan_carries,
    check_vmem_budget,
)
from repro.analysis.contracts import (  # noqa: F401
    DEFAULT_VMEM_BUDGET,
    forbidden_dequant_shapes,
    lint_combo,
    retrace_report,
    run_sweep,
)

__all__ = [
    "Violation", "check_no_dequant", "check_no_quadratic_scores",
    "check_no_host_callback", "check_carry_fixed_point", "check_donation",
    "check_scan_carries", "check_vmem_budget", "forbidden_dequant_shapes",
    "lint_combo", "run_sweep", "retrace_report", "DEFAULT_VMEM_BUDGET",
    "hlo",
]
