"""``python -m repro.analysis``: sweep the serve-graph contracts.

Traces every family x serve-form x mode contract point by abstract eval,
runs the passes, and writes a JSON report. ``--check`` exits non-zero on
any violated contract — the CI gate.

    python -m repro.analysis --check --out analysis_report.json
    python -m repro.analysis --families dense hybrid --modes kernel
    python -m repro.analysis --check --exercise   # + live retrace budgets
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import contracts


def _exercise_retrace():
    """One micro serve (dense/qp/kernel, speculative) so the retrace
    budgets in the report come from REAL compiled-trace counts, not just
    the static graphs. Budgets: the tick compiles once; prefill/admit
    once per admission bucket used (one here)."""
    eng = contracts._engine("dense", "qp", "kernel", spec=True)
    for _ in range(3):
        eng.submit([1, 2, 3, 4], max_new=5)
    eng.step()
    eng.submit([4, 3, 2, 1], max_new=5)       # late wave, same bucket
    eng.run_all()
    budgets = {"tick": 1, "prefill": 1, "admit_many": 1,
               "prefill_draft": 1, "admit_draft_many": 1}
    return contracts.retrace_report(eng, budgets)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static serve-graph contract linter (see README "
                    "'Static analysis & graph contracts').")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any contract is violated (CI gate)")
    ap.add_argument("--families", nargs="+", default=list(contracts.FAMILIES),
                    choices=list(contracts.FAMILIES))
    ap.add_argument("--forms", nargs="+", default=list(contracts.FORMS),
                    choices=list(contracts.FORMS))
    ap.add_argument("--modes", nargs="+", default=list(contracts.MODES),
                    choices=list(contracts.MODES))
    ap.add_argument("--vmem-budget", type=int,
                    default=contracts.DEFAULT_VMEM_BUDGET,
                    help="per-kernel VMEM budget in bytes "
                         "(default: %(default)s, one TPU core)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the JSON report here")
    ap.add_argument("--exercise", action="store_true",
                    help="also run one micro serve and report live jit "
                         "retrace counts against budgets")
    args = ap.parse_args(argv)

    report = contracts.run_sweep(
        args.families, args.forms, args.modes,
        vmem_budget=args.vmem_budget,
        progress=lambda combo: print(f"  lint {combo}", flush=True))
    if args.exercise:
        print("  exercise dense/qp/kernel (spec) for retrace counts",
              flush=True)
        report["retrace"] = _exercise_retrace()

    n_viol = report["violations"] + len(
        report.get("retrace", {}).get("violations", []))
    for combo in report["combos"]:
        for rec in combo["points"]:
            for name, viols in rec["checks"].items():
                for v in viols:
                    print(f"VIOLATION {combo['family']}/{combo['form']}/"
                          f"{combo['mode']} {rec['point']}: {v['check']}: "
                          f"{v['message']}"
                          + (f" [at: {v['eqn']}]" if v.get("eqn") else ""))
    for v in report.get("retrace", {}).get("violations", []):
        print(f"VIOLATION retrace: {v['message']}")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"report -> {args.out}")
    print(f"{report['checks']} checks across "
          f"{len(report['combos'])} combos: {n_viol} violation(s)")
    return 1 if (args.check and n_viol) else 0


if __name__ == "__main__":
    sys.exit(main())
