"""The contract-point registry and the family x form x mode sweep.

A "contract point" is one jitted serving graph the engine runs — decode
tick, bucketed prefill admission, speculative tick, multi-slot admit, and
the module-level ``generate`` loop. Engines describe their own points
abstractly (``ServingEngine.contract_points``), this module builds reduced
configs for every family, captures each point's jaxpr by abstract eval
only (``jax.make_jaxpr`` over engine state + ShapeDtypeStructs — nothing
executes), and runs the passes that apply:

  kernel mode      no_dequant (clean + lowered to pallas_call),
                   no_quadratic_scores (full-attention prefill + verify),
                   vmem_budget, no_host_callback, carry_dtype, donation
  fallback mode    the SAME dequant/score detectors must TRIP (the
                   fallback graphs are the reference signal — if they stop
                   tripping, the kernel-mode checks are vacuous), plus
                   no_host_callback / carry_dtype / donation, which hold
                   in every mode.

The quadratic-score pass applies to full-attention prefill only
(dense/moe): the SSD chunked scan (ssm, hybrid's mamba groups) builds an
intra-chunk (c, c) masked matmul BY DESIGN — quadratic in the chunk
length, linear overall — so a (T, T) tensor in its prefill is not a
violation. Verify (spec_tick) is checked for every attention-bearing
family: T = spec_k+1 there, far below the SSD chunk size.

``retrace_report`` folds the engine's jit trace counts into the same
report shape, so retrace budgets live next to the graph contracts.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analysis import passes
from repro.analysis.vmem import DEFAULT_VMEM_BUDGET, pallas_vmem_estimate
from repro.analysis.jaxpr_utils import find_pallas_eqns
from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import W3A8
from repro.core.treeutil import flatten_with_path, role_of

__all__ = ["FAMILIES", "FORMS", "MODES", "ARCH_FOR", "DEFAULT_VMEM_BUDGET",
           "forbidden_dequant_shapes", "lint_combo", "run_sweep",
           "retrace_report"]

# weight-only 3-bit: the serve policy every registry graph is linted under
W3 = dataclasses.replace(W3A8, act_bits=None)

FAMILIES = ("dense", "moe", "ssm", "hybrid")
FORMS = ("q", "qp")
MODES = ("kernel", "fallback")

ARCH_FOR = {"dense": "qwen2-1.5b", "moe": "phi3.5-moe-42b-a6.6b",
            "ssm": "mamba2-2.7b", "hybrid": "zamba2-1.2b"}

# registry engine geometry: tiny but exercising every path. max_len (48)
# is deliberately distinct from the reduced vocab (64) and d_model (32) so
# the (T, S) score predicate can't collide with logits or residuals.
SLOTS, MAX_LEN, SPEC_K = 2, 48, 2


def forbidden_dequant_shapes(float_params, policy=W3) -> set:
    """Shapes a dequantized weight matrix would have in a serve graph:
    each quantizable leaf's full (stacked) shape and its per-layer slice.
    (Shared by the no_dequant pass here and tests/test_kernel_dispatch.)"""
    shapes = set()
    for path, leaf in flatten_with_path(float_params).items():
        if not (path.endswith("/w") or path == "w"):
            continue
        if policy.spec_for(role_of(path)) is None:
            continue
        nd = quant_dense._stacked_dims(path)
        shapes.add(tuple(leaf.shape))
        shapes.add(tuple(leaf.shape[nd:]))
    return shapes


@functools.lru_cache(maxsize=None)
def _family_setup(family: str):
    from repro.models import get_model
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@functools.lru_cache(maxsize=None)
def _serve_setup(family: str, form: str):
    cfg, params = _family_setup(family)
    export = (quant_dense.export_levels if form == "q"
              else quant_dense.export_container)
    return cfg, export(params, W3), params


def _mode_kwargs(mode: str) -> Dict[str, str]:
    return (dict(matmul_mode="kernel", attn_mode="kernel") if mode == "kernel"
            else dict(matmul_mode="dequant", attn_mode="ref"))


def _engine(family: str, form: str, mode: str, *, spec: bool):
    from repro.serving.engine import ServingEngine
    cfg, sp, _ = _serve_setup(family, form)
    return ServingEngine(sp, cfg, policy=W3, slots=SLOTS, max_len=MAX_LEN,
                         dtype=jnp.float32, attn_chunk=MAX_LEN,
                         spec_k=SPEC_K if spec else 0, **_mode_kwargs(mode))


def _generate_point(cfg, serve_params, mode: str) -> Dict[str, Any]:
    """The module-level ``generate`` loop as a contract point: prefill +
    jitted scan over decode_step, captured over an abstract prompt."""
    from repro.serving.engine import generate
    prompts = jax.ShapeDtypeStruct((1, 4), jnp.int32)
    kw = dict(policy=W3, max_new_tokens=4, dtype=jnp.float32,
              **_mode_kwargs(mode))
    return dict(name="generate_loop",
                fn=lambda pr: generate(serve_params, pr, cfg, **kw),
                args=(prompts,), donate=(), carry={}, score_dims=None)


def _scores_apply(family: str, point: str) -> bool:
    if point == "prefill_bucketed":
        return family in ("dense", "moe")     # full-attention prefill only
    if point == "spec_tick":
        return family != "ssm"
    return False


def _verify_point(family: str, form: str, mode: str) -> Optional[Dict]:
    """Model-level multi-token verify over an abstract live cache — the
    contract point threaded through ``models/api.py``: the cache comes
    from ``api.init_cache_abstract`` (zero allocation), the graph from
    ``api.verify_step``."""
    from repro.models import api as model_api
    from repro.models import get_model
    if family == "ssm":
        return None
    cfg, sp, _ = _serve_setup(family, form)
    mod = get_model(cfg)
    t = SPEC_K + 1
    s = (mod.cache_len_for(cfg, MAX_LEN)
         if hasattr(mod, "cache_len_for") else MAX_LEN)
    cache = model_api.init_cache_abstract(cfg, SLOTS, MAX_LEN, jnp.float32,
                                          per_slot_len=True)
    toks = jax.ShapeDtypeStruct((SLOTS, t), jnp.int32)
    mkw = _mode_kwargs(mode)

    def fn(c, tk):
        return model_api.verify_step(sp, c, tk, cfg, policy=W3,
                                     dtype=jnp.float32, **mkw)
    return dict(name="verify", fn=fn, args=(cache, toks), donate=(),
                carry={}, score_dims=(t, s))


def _point_checks(point: Dict[str, Any], jaxpr, *, mode: str, family: str,
                  forbidden: set, vmem_budget: int) -> Dict[str, List]:
    """Which passes gate this point in this mode -> their violations."""
    name = point["name"]
    kernel = mode == "kernel"
    checks: Dict[str, List[passes.Violation]] = {
        "no_host_callback": passes.check_no_host_callback(jaxpr),
        "scan_carries": passes.check_scan_carries(jaxpr),
    }
    if kernel:
        # admit_many is a pure multi-slot scatter — no matmul, hence no
        # pallas_call to demand; it must still not materialize weights
        checks["no_dequant"] = passes.check_no_dequant(
            jaxpr, forbidden, require_pallas=name != "admit_many")
        checks["vmem_budget"] = passes.check_vmem_budget(jaxpr, vmem_budget)
        if point["score_dims"] and _scores_apply(family, name):
            t, s = point["score_dims"]
            checks["no_quadratic_scores"] = passes.check_no_quadratic_scores(
                jaxpr, t, s, require_pallas=True)
    else:
        # detector sanity: the fallback graphs ARE the reference signal —
        # the dequant path casts levels to (K, N) floats and the ref
        # attention builds (.., T, S) chunk tiles, so the same detectors
        # must trip here or the kernel-mode checks are vacuous
        if name in ("decode_tick", "spec_tick", "prefill_bucketed",
                    "generate_loop", "verify"):
            hit = passes.check_no_dequant(jaxpr, forbidden,
                                          require_pallas=False)
            checks["no_dequant_signal"] = [] if hit else [passes.Violation(
                "no_dequant_signal",
                f"{name}: the dequant-fallback graph no longer trips the "
                f"dequant detector — the kernel-mode no_dequant check is "
                f"vacuous")]
        if point["score_dims"] and _scores_apply(family, name):
            t, s = point["score_dims"]
            hit = passes.check_no_quadratic_scores(jaxpr, t, s)
            checks["no_quadratic_scores_signal"] = [] if hit else [
                passes.Violation(
                    "no_quadratic_scores_signal",
                    f"{name}: the ref-attention graph no longer trips the "
                    f"(T={t}, S={s}) score detector — the kernel-mode "
                    f"check is vacuous")]
    if point["carry"]:
        checks["carry_dtype"] = passes.check_carry_fixed_point(
            point["fn"], point["args"], point["carry"], point=name)
    if point["donate"]:
        checks["donation"] = passes.check_donation(
            point["fn"], point["args"], point["donate"], point=name)
    return checks


def lint_combo(family: str, form: str, mode: str, *,
               vmem_budget: int = DEFAULT_VMEM_BUDGET) -> List[Dict]:
    """Lint every contract point of one family x serve-form x mode combo.

    Returns one record per point: ``{"point", "checks": {pass: [violation
    dicts]}, "kernels": [vmem estimates]}`` — empty violation lists mean
    the contract holds.
    """
    cfg, sp, float_params = _serve_setup(family, form)
    forbidden = forbidden_dequant_shapes(float_params, W3)
    points = _engine(family, form, mode, spec=False).contract_points()
    if family != "ssm":
        points += [p for p in
                   _engine(family, form, mode, spec=True).contract_points()
                   if p["name"] == "spec_tick"]
        vp = _verify_point(family, form, mode)
        if vp:
            points.append(vp)
    points.append(_generate_point(cfg, sp, mode))
    out = []
    for p in points:
        jaxpr = jax.make_jaxpr(p["fn"])(*p["args"])
        checks = _point_checks(p, jaxpr, mode=mode, family=family,
                               forbidden=forbidden, vmem_budget=vmem_budget)
        rec = {"point": p["name"],
               "checks": {k: [v.to_dict() for v in vs]
                          for k, vs in checks.items()}}
        if mode == "kernel":
            rec["kernels"] = [
                {k: est[k] for k in
                 ("name", "grid", "vmem_bytes", "smem_bytes")}
                for est in map(pallas_vmem_estimate,
                               find_pallas_eqns(jaxpr))]
        out.append(rec)
    return out


def run_sweep(families: Sequence[str] = FAMILIES,
              forms: Sequence[str] = FORMS,
              modes: Sequence[str] = MODES, *,
              vmem_budget: int = DEFAULT_VMEM_BUDGET,
              progress=None) -> Dict[str, Any]:
    """The full contract sweep -> the JSON report the CI gate uploads."""
    combos, n_checks, n_viol = [], 0, 0
    for family in families:
        for form in forms:
            for mode in modes:
                if progress:
                    progress(f"{family}/{form}/{mode}")
                recs = lint_combo(family, form, mode,
                                  vmem_budget=vmem_budget)
                nv = sum(len(v) for r in recs for v in r["checks"].values())
                n_checks += sum(len(r["checks"]) for r in recs)
                n_viol += nv
                combos.append({"family": family, "form": form, "mode": mode,
                               "violations": nv, "points": recs})
    return {"vmem_budget": vmem_budget, "checks": n_checks,
            "violations": n_viol, "combos": combos}


def retrace_report(engine, budgets: Optional[Dict[str, int]] = None
                   ) -> Dict[str, Any]:
    """Trace-count report from the engine's jit registry, in the same
    shape as the contract checks: ``{"counts", "budgets", "violations"}``.
    A healthy engine compiles its tick ONCE per run; the bucketed prefill
    O(#admission buckets) times. Pass ``budgets`` as {jit name: max
    traces} — names from ``ServingEngine.trace_counts()``."""
    counts = engine.trace_counts()
    budgets = dict(budgets or {})
    viols = []
    for name, limit in sorted(budgets.items()):
        n = counts.get(name, 0)
        if n > limit:
            viols.append(passes.Violation(
                "retrace_budget",
                f"jit '{name}' compiled {n} traces, budget {limit} — "
                f"an input aval is drifting between calls").to_dict())
    return {"counts": counts, "budgets": budgets, "violations": viols}
