"""Shared jaxpr walkers for the contract passes.

These are THE copies of the scan helpers that used to be triplicated across
``tests/test_kernel_dispatch.py`` / ``tests/test_attn_prefill.py`` /
``tests/test_engine_spec.py`` — same semantics (pallas_call bodies are not
descended into by default: their VMEM tiles are the point of the kernels),
plus eqn attribution so lint messages can name the offending equation.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

import jax.numpy as jnp
from jax.core import ClosedJaxpr, Jaxpr

__all__ = ["subjaxprs", "as_jaxpr", "iter_eqns", "eqn_label",
           "float_shapes_outside_pallas", "find_pallas_eqns"]


def subjaxprs(val) -> Iterator[Jaxpr]:
    """Yield every Jaxpr reachable from one eqn-params value."""
    if isinstance(val, ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, Jaxpr):
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from subjaxprs(v)


def as_jaxpr(jaxpr) -> Jaxpr:
    return jaxpr.jaxpr if isinstance(jaxpr, ClosedJaxpr) else jaxpr


def iter_eqns(jaxpr, *, descend_pallas: bool = False):
    """Depth-first over every eqn of ``jaxpr`` and its sub-jaxprs.

    ``pallas_call`` eqns are always yielded; their kernel BODIES are only
    descended into with ``descend_pallas=True``.
    """
    stack = [as_jaxpr(jaxpr)]
    while stack:
        jx = stack.pop()
        for eqn in jx.eqns:
            yield eqn
            if eqn.primitive.name == "pallas_call" and not descend_pallas:
                continue
            for val in eqn.params.values():
                stack.extend(subjaxprs(val))


def _aval_str(aval) -> str:
    if hasattr(aval, "dtype") and hasattr(aval, "shape"):
        return f"{jnp.dtype(aval.dtype).name}{list(aval.shape)}"
    return str(aval)


def eqn_label(eqn) -> str:
    """Short human label naming an equation: primitive -> result avals."""
    outs = ", ".join(_aval_str(v.aval) for v in eqn.outvars
                     if hasattr(v, "aval"))
    name = eqn.primitive.name
    if name == "pallas_call":
        info = eqn.params.get("name_and_src_info")
        kname = getattr(info, "name", None) or eqn.params.get("name", "")
        name = f"pallas_call[{kname}]" if kname else name
    return f"{name} -> {outs}" if outs else name


def float_shapes_outside_pallas(jaxpr) -> Tuple[Dict[tuple, str], bool]:
    """All float-dtype result shapes in the graph, NOT descending into
    pallas_call bodies (their VMEM tiles are the point of the kernel).

    Returns ``({shape: label of the first eqn producing it}, saw_pallas)``
    — the keys are exactly the set the old test-local scanners returned,
    the labels are what lint messages attribute violations to.
    """
    shapes: Dict[tuple, str] = {}
    saw = False
    for eqn in iter_eqns(jaxpr, descend_pallas=False):
        if eqn.primitive.name == "pallas_call":
            saw = True
            continue
        for v in eqn.outvars:
            aval = v.aval
            if (hasattr(aval, "dtype")
                    and jnp.issubdtype(aval.dtype, jnp.floating)):
                shapes.setdefault(tuple(aval.shape), eqn_label(eqn))
    return shapes, saw


def find_pallas_eqns(jaxpr) -> List:
    """Every pallas_call eqn in the graph (not nested inside another)."""
    return [eqn for eqn in iter_eqns(jaxpr, descend_pallas=False)
            if eqn.primitive.name == "pallas_call"]
