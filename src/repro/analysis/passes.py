"""The six contract passes. Each is a pure function over a closed jaxpr
(or, for the whole-function checks, an abstract-evaluable callable) and
returns a list of :class:`Violation` — empty means the contract holds.
Nothing here executes a graph: jaxprs come from ``jax.make_jaxpr``, avals
from ``jax.eval_shape``, donation from ``jax.jit(...).lower`` on
ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Iterable, List, Sequence

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.analysis.jaxpr_utils import (eqn_label, find_pallas_eqns,
                                        float_shapes_outside_pallas,
                                        iter_eqns)
from repro.analysis.vmem import DEFAULT_VMEM_BUDGET, pallas_vmem_estimate

__all__ = ["Violation", "check_no_dequant", "check_no_quadratic_scores",
           "check_no_host_callback", "check_scan_carries",
           "check_carry_fixed_point", "check_donation", "check_vmem_budget"]


@dataclasses.dataclass
class Violation:
    """One broken contract: which pass fired, an actionable message, and
    (when attributable) the offending equation."""
    check: str
    message: str
    eqn: str = ""

    def __str__(self) -> str:
        loc = f" [at: {self.eqn}]" if self.eqn else ""
        return f"{self.check}: {self.message}{loc}"

    def to_dict(self) -> Dict[str, str]:
        return dataclasses.asdict(self)


# --- pass 1: no dequantized weight tensor -----------------------------------------

def check_no_dequant(jaxpr, forbidden_shapes: Iterable[tuple], *,
                     require_pallas: bool = True) -> List[Violation]:
    """No float tensor of a quantized weight's (stacked or per-layer) shape
    may appear outside the Pallas kernels: a hit means the graph
    materialized a dequantized weight matrix in HBM — exactly what the
    3-bit serve forms exist to avoid. ``require_pallas`` additionally
    demands the graph actually lowered to pallas_call (kernel mode that
    silently fell back to a fallback path is itself a violation)."""
    shapes, saw = float_shapes_outside_pallas(jaxpr)
    forbidden = set(map(tuple, forbidden_shapes))
    out = [Violation("no_dequant",
                     f"float tensor of quantized-weight shape {sh} is "
                     f"materialized outside the Pallas kernels (dequantized "
                     f"weight in the serve graph)", eqn=shapes[sh])
           for sh in sorted(set(shapes) & forbidden)]
    if require_pallas and not saw:
        out.append(Violation("no_dequant",
                             "graph contains no pallas_call: kernel mode "
                             "did not lower to the Pallas kernels"))
    return out


# --- pass 2: no quadratic score tensor --------------------------------------------

def check_no_quadratic_scores(jaxpr, t: int, s: int, *, min_rank: int = 2,
                              require_pallas: bool = False) -> List[Violation]:
    """No float tensor whose trailing dims are (T, S) may appear outside
    the Pallas kernels in a kernel-mode prefill/verify graph: the blocked
    online-softmax kernel keeps the score tile in VMEM, so a full (..., T,
    S) float result means the quadratic HBM intermediate is back.
    ``min_rank`` filters accidental shape collisions at coarse contract
    points (real attention score tensors are (B, KV, G, T, S))."""
    shapes, saw = float_shapes_outside_pallas(jaxpr)
    out = [Violation("no_quadratic_scores",
                     f"float score tensor {sh} with trailing dims "
                     f"(T={t}, S={s}) materialized outside the Pallas "
                     f"kernels (quadratic HBM intermediate)", eqn=shapes[sh])
           for sh in sorted(shapes)
           if len(sh) >= max(2, min_rank) and tuple(sh[-2:]) == (t, s)]
    if require_pallas and not saw:
        out.append(Violation("no_quadratic_scores",
                             "graph contains no pallas_call: kernel mode "
                             "did not lower to the Pallas kernels"))
    return out


# --- pass 3: no host callback / transfer ------------------------------------------

# primitive names that sync with or transfer to the host: any callback
# flavor (pure_callback / io_callback / debug_callback) plus explicit
# placement/transfer ops. A jitted serving tick containing one of these
# cannot be async — it re-introduces the per-token host sync.
_TRANSFER_PRIMS = ("device_put", "infeed", "outfeed")


def check_no_host_callback(jaxpr) -> List[Violation]:
    out = []
    for eqn in iter_eqns(jaxpr, descend_pallas=True):
        name = eqn.primitive.name
        if "callback" in name or name in _TRANSFER_PRIMS:
            out.append(Violation(
                "no_host_callback",
                f"host-sync primitive '{name}' inside a jitted serving "
                f"graph (breaks the async no-per-token-sync contract)",
                eqn=eqn_label(eqn)))
    return out


# --- pass 4: carry dtype drift ----------------------------------------------------

def _leaf_sig(x):
    return tuple(x.shape), jnp.dtype(x.dtype)


def check_carry_fixed_point(fn, args: Sequence, carry_map: Dict[int, int],
                            *, point: str = "") -> List[Violation]:
    """Abstract-eval ``fn(*args)`` and require every carried buffer to be
    an aval FIXED POINT: ``carry_map`` maps input argnum -> output index,
    and each mapped pair must agree leaf-for-leaf in shape and dtype.

    This is the static catcher for the PR 5 ``mamba2.block_decode`` bug
    class: a tick whose output cache drifts to a different dtype than its
    input cache silently retraces on every invocation (and breaks any
    scan/while carry built over it). Args may be concrete arrays or
    ShapeDtypeStructs — nothing is executed."""
    label = point or getattr(fn, "__name__", "fn")
    # a fresh wrapper object per call: jax caches abstract-eval traces
    # keyed on the function object, and a stale trace would hide drift
    # introduced after a previous clean check of the same fn
    out = jax.eval_shape(lambda *a: fn(*a), *args)
    if not isinstance(out, (tuple, list)):
        out = (out,)
    viols: List[Violation] = []
    for argnum, outidx in sorted(carry_map.items()):
        fin, tin = jtu.tree_flatten_with_path(args[argnum])
        fout, tout = jtu.tree_flatten_with_path(out[outidx])
        if tin != tout:
            viols.append(Violation(
                "carry_dtype",
                f"{label}: carried arg {argnum} -> output {outidx} changed "
                f"pytree structure across the tick"))
            continue
        for (path, a), (_, b) in zip(fin, fout):
            if _leaf_sig(a) != _leaf_sig(b):
                viols.append(Violation(
                    "carry_dtype",
                    f"{label}: carried arg {argnum}{jtu.keystr(path)} is "
                    f"{jnp.dtype(a.dtype).name}{list(a.shape)} going in but "
                    f"{jnp.dtype(b.dtype).name}{list(b.shape)} coming out — "
                    f"not an aval fixed point, so every tick retraces "
                    f"(and a scan/while carry over it fails)"))
    return viols


def check_scan_carries(jaxpr) -> List[Violation]:
    """Defense-in-depth companion: every scan/while carry INSIDE the graph
    must keep fixed avals across iterations. JAX enforces this at trace
    time for its own control-flow primitives, so on today's jax a traced
    graph can't violate it — but custom primitives and future versions
    can, and the check documents the invariant where the report lives."""
    out = []
    for eqn in iter_eqns(jaxpr, descend_pallas=False):
        if eqn.primitive.name == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            nc, ncarry = eqn.params["num_consts"], eqn.params["num_carry"]
            pairs = zip(inner.invars[nc:nc + ncarry], inner.outvars[:ncarry])
        elif eqn.primitive.name == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            nc = eqn.params["body_nconsts"]
            pairs = zip(body.invars[nc:], body.outvars)
        else:
            continue
        for i, (a, b) in enumerate(pairs):
            aa, bb = getattr(a, "aval", None), getattr(b, "aval", None)
            if aa is None or bb is None:
                continue
            if (tuple(aa.shape), jnp.dtype(aa.dtype)) != \
                    (tuple(bb.shape), jnp.dtype(bb.dtype)):
                out.append(Violation(
                    "carry_dtype",
                    f"{eqn.primitive.name} carry {i} drifts "
                    f"{jnp.dtype(aa.dtype).name}{list(aa.shape)} -> "
                    f"{jnp.dtype(bb.dtype).name}{list(bb.shape)} across "
                    f"iterations", eqn=eqn_label(eqn)))
    return out


# --- pass 5: donation honored -----------------------------------------------------

def check_donation(fn, args: Sequence, donate_argnums: Sequence[int], *,
                   point: str = "") -> List[Violation]:
    """Lower a FRESH ``jax.jit(fn, donate_argnums=...)`` over the given
    (possibly abstract) args and require the donation to take: every
    "donated buffers were not usable" warning is a violation (the aliasing
    fallback path — the tick would silently copy the whole cache), and at
    least one input must actually alias an output in the lowered module.
    Building a private jit keeps the check from polluting the caller's jit
    caches (trace-count budgets stay honest)."""
    label = point or getattr(fn, "__name__", "fn")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        # fresh wrapper: same trace-cache-staleness defense as the carry
        # pass, and it guarantees this private jit shares no cache with
        # the caller's jitted fns (trace-count budgets stay honest)
        text = jax.jit(lambda *a: fn(*a),
                       donate_argnums=tuple(donate_argnums)) \
            .lower(*args).as_text()
    viols = []
    for w in caught:
        msg = str(w.message)
        if "donated" in msg.lower():
            viols.append(Violation(
                "donation",
                f"{label}: donation fell back to a copy — {msg[:300]}"))
    if "tf.aliasing_output" not in text:
        viols.append(Violation(
            "donation",
            f"{label}: no donated input aliases any output "
            f"(donate_argnums={tuple(donate_argnums)} had no effect; the "
            f"cache is copied every call)"))
    return viols


# --- pass 6: Pallas VMEM budget ---------------------------------------------------

def check_vmem_budget(jaxpr, budget_bytes: int = DEFAULT_VMEM_BUDGET,
                      ) -> List[Violation]:
    """Every pallas_call's estimated on-chip working set (double-buffered
    block tiles + scratch, from the BlockSpecs/grid — see
    :func:`repro.analysis.vmem.pallas_vmem_estimate`) must fit the VMEM
    budget. This is the paper's on-chip-memory contract in bytes."""
    out = []
    for eqn in find_pallas_eqns(jaxpr):
        est = pallas_vmem_estimate(eqn)
        if est["vmem_bytes"] > budget_bytes:
            big = sorted((r for r in est["refs"] if r[0] != "prefetch"),
                         key=lambda r: -r[3])[:3]
            detail = ", ".join(f"{k} {d}{list(sh)} = {b} B"
                               for k, sh, d, b in big)
            out.append(Violation(
                "vmem_budget",
                f"kernel '{est['name']}' (grid {est['grid']}) estimated "
                f"VMEM {est['vmem_bytes']} B exceeds budget "
                f"{budget_bytes} B; largest refs: {detail}",
                eqn=eqn_label(eqn)))
    return out
