"""Post-SPMD HLO analysis: collective-bytes extraction + cost decomposition.

The compiled-artifact backend of ``repro.analysis`` (formerly
``repro.launch.hlo_analysis``; that module re-exports from here). The jaxpr
passes in ``repro.analysis.passes`` see graphs BEFORE compilation; this
module reads what XLA actually produced.

``collective_bytes``: per the roofline spec, sums *operand* sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute in
the optimized (partitioned) HLO — shapes there are per-partition, so totals
are per-chip wire-byte proxies.

XLA's HloCostAnalysis visits a while-loop body ONCE regardless of trip count
(verified empirically — see EXPERIMENTS.md §Dry-run methodology), so totals
for scanned-layer models are reconstructed by the L0/L1 lowering
decomposition in launch.dryrun, not by trip-count guessing here. The flat
per-text counts this module returns are exactly "body counted once".
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["collective_bytes", "DTYPE_BYTES", "cost_summary",
           "memory_summary", "_shape_bytes"]

# bytes per element. The packed serve forms put sub-byte and 8-bit codes on
# the wire: s4/u4 are bit-packed two-per-byte by XLA (0.5), and the f8
# variants are all one byte regardless of exponent/mantissa split.
DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 0.5, "u4": 0.5,
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\])")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)")

KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return int(total)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """{kind: operand bytes (flat, body-once)} + 'total' + 'count'."""
    # pass 1: result shapes of every definition
    shapes: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    out: Dict[str, float] = defaultdict(float)
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        _, kind, operands = m.group(1), m.group(2), m.group(3)
        if "-done" in line.split("=")[1][:40]:
            continue
        b = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            # operands may carry inline shapes (newer HLO) or be refs
            ms = _SHAPE_RE.match(op)
            if ms:
                b += _shape_bytes(op.split(" ")[0])
            elif op in shapes:
                b += _shape_bytes(shapes[op])
        out[kind] += b
        count += 1
    out["total"] = sum(out[k] for k in KINDS if k in out)
    out["count"] = count
    return dict(out)


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        # peak live estimate: args + temps + outputs - aliased(donated)
        "peak_bytes_est": float(ma.argument_size_in_bytes
                                + ma.temp_size_in_bytes
                                + ma.output_size_in_bytes
                                - ma.alias_size_in_bytes),
    }
