"""Per-kernel VMEM footprint estimation from pallas_call BlockSpecs/grid.

The paper's on-chip-memory contract, stated in bytes: a TPU core has
~16 MiB of VMEM, and a Pallas kernel's working set — every block-mapped
input/output tile (double-buffered by the pipeline: the compiler prefetches
block i+1 while block i computes) plus scratch allocations — must fit in
it, or the kernel either fails to compile on hardware or silently spills.

The estimate is read off the traced ``pallas_call`` eqn alone, no
execution: the kernel jaxpr's invars ARE the per-block refs (block shapes
with squeezed dims removed, real dtypes, memory spaces), partitioned by the
grid mapping into [scalar-prefetch][inputs][outputs][scratch].
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax.numpy as jnp

__all__ = ["DEFAULT_VMEM_BUDGET", "pallas_vmem_estimate"]

# one TPU core's VMEM (~16 MiB): the hard on-chip ceiling the double-
# buffered working set must stay under
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024


def _ref_bytes(aval) -> int:
    n = 1
    for d in aval.shape:
        n *= int(d)
    return n * jnp.dtype(aval.dtype).itemsize


def _is_smem(aval) -> bool:
    return "smem" in str(getattr(aval, "memory_space", "")).lower()


def pallas_vmem_estimate(eqn) -> Dict[str, Any]:
    """Estimate one pallas_call eqn's on-chip footprint.

    Returns ``{name, grid, vmem_bytes, smem_bytes, refs}`` where ``refs``
    itemizes every kernel ref as ``(kind, shape, dtype, bytes)`` with
    kind in {prefetch, in, out, scratch}. Inputs/outputs count x2
    (pipeline double buffering), scratch and scalar-prefetch count once.
    """
    gm = eqn.params["grid_mapping"]
    kernel_jaxpr = eqn.params["jaxpr"]
    n_idx = gm.num_index_operands
    n_in, n_out = gm.num_inputs, gm.num_outputs
    n_scratch = gm.num_scratch_operands
    invars = kernel_jaxpr.invars
    kinds = (["prefetch"] * n_idx + ["in"] * n_in + ["out"] * n_out
             + ["scratch"] * n_scratch)
    vmem = smem = 0
    refs: List[tuple] = []
    for kind, v in zip(kinds, invars):
        aval = v.aval
        b = _ref_bytes(aval)
        mult = 2 if kind in ("in", "out") else 1
        if kind == "prefetch" or _is_smem(aval):
            smem += b
        else:
            vmem += b * mult
        refs.append((kind, tuple(aval.shape), jnp.dtype(aval.dtype).name, b))
    info = eqn.params.get("name_and_src_info")
    name = getattr(info, "name", None) or eqn.params.get("name", "pallas_call")
    return {"name": name, "grid": tuple(gm.grid), "vmem_bytes": int(vmem),
            "smem_bytes": int(smem), "refs": refs}
