"""Pallas TPU kernel: blocked online-softmax prefill/verify attention —
(B, T, KV, G, D) queries against (B, S, KV, D) keys/values, bf16/f32 or
int8 with per-token scales, per-(row, query) visibility bounds.

This is the flash-attention analogue of the paper's on-chip dataflow applied
to the two multi-token serving paths: bucketed-prefill admission (T = the
admission bucket, S = T, self-attention over the prompt) and speculative
verify (T = spec_k+1 draft rows, S = the live cache). The plain einsum
paths materialize a full fp32 (B, KV, G, T, S) score tensor in HBM per
layer — quadratic in the prompt for admission, and the per-tick latency
floor of speculative verify. Here the (bt, G, bs) score tile is the ONLY
score storage and it never leaves VMEM:

  * QK^T -> online softmax -> PV fused per tile; the running (m, l, acc)
    flash carry lives in VMEM scratch across the S grid dimension.
  * Per-(row, query) masking: query ``t`` of row ``b`` sees key positions
    ``lo[b, t] <= p < hi[b, t]``. Bucketed prefill sets
    ``hi = min(t+1, lengths[b])`` (causal AND padded tail masked per row —
    the bucketed-prefill rule), verify passes its ``valid`` counts, and a
    sliding window raises ``lo`` to ``t - window + 1``.
  * DMA-level block skipping: the scalar-prefetched per-(row, q-block)
    bounds clamp the K/V index maps, so S blocks entirely past ``hi`` (the
    causal upper triangle + padded tails) or before ``lo`` (outside the
    window) re-target an adjacent block — same index as the previous grid
    step, so the pipeline elides the HBM->VMEM copy — and ``pl.when``
    skips their compute.
  * Fused dequant epilogue: an int8 K/V source is read directly; per-token
    scales factor through the contractions exactly as in the einsum paths
    (scores * k_scale after QK^T, p * v_scale into the probabilities
    before PV) — the engine's ``kv_bits=8`` cache needs no dequant pass.

Grid: (B, T/bt, KV, S/bs), S innermost ("arbitrary" — sequential
accumulation into the scratch carry). One q block is (bt, G, D) for a
single kv head; K/V blocks are (bs, D).

Numerics match ``attn_prefill_ref`` (ref.py): fp32 scores and softmax
statistics, probabilities cast to the compute dtype for PV, fp32
accumulator, one cast to the query dtype at the end. Rows whose visible
range is empty (``hi <= lo``) produce zeros — the same empty-row guard as
``attn_decode`` (a raw softmax over pure NEG_INF would emit the uniform
average, or NaN with a true -inf fill).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["attn_prefill_pallas", "NEG_INF"]

NEG_INF = -1e30


def _kernel(hmax_ref, lmin_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
            lo_ref, hi_ref, o_ref, acc_ref, m_ref, l_ref, *, bs: int,
            quantized: bool):
    """One (bt, G) q tile of one batch row against one (bs,) K/V block.

    Refs: q (1, bt, 1, G, D); k/v (1, bs, 1, D); ks/vs (1, bs) fp32 scales
    (None when not quantized); lo/hi (1, bt) int32; out (1, bt, 1, G, D).
    Scratch: acc (bt, G, D) fp32; m/l (bt, G) fp32 — the online-softmax
    carry, valid across the innermost S grid dimension.
    """
    i = pl.program_id(0)
    t = pl.program_id(1)
    s_blk = pl.program_id(3)
    start = s_blk * bs

    @pl.when(s_blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip blocks with no visible position for ANY query of this tile
    # (their K/V DMA was already elided by the clamped index maps)
    @pl.when((start < hmax_ref[i, t]) & (start + bs > lmin_ref[i, t]))
    def _compute():
        q = q_ref[0, :, 0]                              # (bt, G, D)
        k = k_ref[0, :, 0]                              # (bs, D)
        sc = jax.lax.dot_general(                       # (bt, G, bs) fp32
            q, k.astype(q.dtype),
            dimension_numbers=(((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        if quantized:
            sc = sc * ks_ref[0].astype(jnp.float32)[None, None, :]
        pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (sc.shape[0], bs), 1)            # (bt, bs)
        valid = (pos < hi_ref[0][:, None]) & (pos >= lo_ref[0][:, None])
        sc = jnp.where(valid[:, None, :], sc, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        # `alive` guards rows with no valid position yet: m_new == NEG_INF
        # there, and exp(sc - m_new) would be exp(0) = 1 for masked slots
        alive = m_new > NEG_INF / 2
        p = jnp.where(alive[..., None],
                      jnp.exp(sc - m_new[..., None]), 0.0)  # (bt, G, bs)
        corr = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        v = v_ref[0, :, 0]                              # (bs, D)
        if quantized:
            p = (p * vs_ref[0].astype(jnp.float32)[None, None, :]
                 ).astype(q.dtype)
            v = v.astype(q.dtype)
        else:
            p = p.astype(v.dtype)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s_blk == pl.num_programs(3) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)              # (bt, G)
        o_ref[...] = (acc_ref[...] / l[..., None]
                      )[None, :, None].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bt", "bs", "interpret"))
def attn_prefill_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        lo: jnp.ndarray, hi: jnp.ndarray,
                        k_scale: jnp.ndarray | None = None,
                        v_scale: jnp.ndarray | None = None, *,
                        bt: int = 128, bs: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """q (B, T, KV, G, D) PRE-SCALED by 1/sqrt(D); k/v (B, S, KV, D);
    lo/hi (B, T) int32 per-query visibility bounds (query t of row b sees
    positions lo <= p < hi); optional per-token scales (B, S) fp32 for an
    int8 K/V source. Returns (B, T, KV, G, D) in q's dtype.

    ``bt`` query rows x ``bs`` key positions per program; both are clamped
    and the inputs zero-padded, with padded query rows masked via hi = 0
    (the empty-row guard zeroes their output).
    """
    b, t, kv, g, d = q.shape
    s = k.shape[1]
    quantized = k_scale is not None
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.int32), (b, t))
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.int32), (b, t))

    bt = min(bt, t)
    bs = min(bs, s)
    tp = -(-t // bt) * bt
    sp = -(-s // bs) * bs
    if tp != t:
        q = jnp.pad(q, ((0, 0), (0, tp - t)) + ((0, 0),) * 3)
        lo = jnp.pad(lo, ((0, 0), (0, tp - t)))
        hi = jnp.pad(hi, ((0, 0), (0, tp - t)))         # pad queries: hi 0
    if sp != s:
        k = jnp.pad(k, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    if quantized:
        k_scale = jnp.pad(jnp.asarray(k_scale, jnp.float32),
                          ((0, 0), (0, sp - s)))
        v_scale = jnp.pad(jnp.asarray(v_scale, jnp.float32),
                          ((0, 0), (0, sp - s)))
    nt, ns = tp // bt, sp // bs
    # per-(row, q-block) visibility bounds, scalar-prefetched: the index
    # maps clamp the S block index into [first needed, last needed], so
    # blocks past the causal frontier / padded tail (or before the sliding
    # window) re-target an adjacent block — same index as the previous grid
    # step => the pipeline skips the HBM->VMEM copy
    hmax = jnp.max(hi.reshape(b, nt, bt), axis=-1)
    lmin = jnp.min(lo.reshape(b, nt, bt), axis=-1)

    def _sblk(i, tt, s_blk, hmax_ref, lmin_ref):
        nhi = jnp.maximum((hmax_ref[i, tt] + bs - 1) // bs, 1)
        return jnp.minimum(jnp.maximum(s_blk, lmin_ref[i, tt] // bs),
                           nhi - 1)

    def kv_idx(i, tt, j, s_blk, hmax_ref, lmin_ref):
        return (i, _sblk(i, tt, s_blk, hmax_ref, lmin_ref), j, 0)

    def sc_idx(i, tt, j, s_blk, hmax_ref, lmin_ref):
        return (i, _sblk(i, tt, s_blk, hmax_ref, lmin_ref))

    def q_idx(i, tt, j, s_blk, hmax_ref, lmin_ref):
        return (i, tt, j, 0, 0)

    def b_idx(i, tt, j, s_blk, hmax_ref, lmin_ref):
        return (i, tt)

    in_specs = [
        pl.BlockSpec((1, bt, 1, g, d), q_idx),
        pl.BlockSpec((1, bs, 1, d), kv_idx),
        pl.BlockSpec((1, bs, 1, d), kv_idx),
    ]
    args = [q, k, v]
    if quantized:
        in_specs += [pl.BlockSpec((1, bs), sc_idx),
                     pl.BlockSpec((1, bs), sc_idx)]
        args += [k_scale, v_scale]
    in_specs += [pl.BlockSpec((1, bt), b_idx), pl.BlockSpec((1, bt), b_idx)]
    args += [lo, hi]

    if quantized:
        kernel = functools.partial(_kernel, bs=bs, quantized=True)
    else:                  # no scale operands: splice None refs back in
        def kernel(hmax_ref, lmin_ref, q_ref, k_ref, v_ref, lo_ref, hi_ref,
                   o_ref, acc_ref, m_ref, l_ref):
            return _kernel(hmax_ref, lmin_ref, q_ref, k_ref, v_ref, None,
                           None, lo_ref, hi_ref, o_ref, acc_ref, m_ref,
                           l_ref, bs=bs, quantized=False)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nt, kv, ns),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bt, 1, g, d), q_idx),
        scratch_shapes=[
            pltpu.VMEM((bt, g, d), jnp.float32),        # acc
            pltpu.VMEM((bt, g), jnp.float32),           # running max
            pltpu.VMEM((bt, g), jnp.float32),           # running sum
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, tp, kv, g, d), q.dtype),
        interpret=interpret,
    )(hmax, lmin, *args)
    return out[:, :t]
