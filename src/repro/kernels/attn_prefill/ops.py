"""Serving-facing entry point for the blocked prefill/verify attention
kernel: plain (B, T, H, D) in, GQA grouping / 1-sqrt(D) pre-scaling /
visibility-bound plumbing handled here, interpret mode auto-selected off
TPU (same convention as ``attn_decode`` and ``qmatmul``).

Callers express masking as per-query [lo, hi) bounds:

  * bucketed prefill — ``hi = min(t + 1, lengths[row])``: causal within the
    prompt AND the padded tail masked per row (``attn_prefill`` builds this
    from ``lengths``; pass ``window`` to also raise ``lo`` for SWA layers);
  * speculative verify — ``hi = valid`` (B, T), the per-row causal frontier
    over the live cache, built by ``verify_attention``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.attn_prefill.kernel import attn_prefill_pallas
from repro.kernels.qmatmul.ops import on_tpu

__all__ = ["attn_prefill"]


def attn_prefill(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 hi: jnp.ndarray, lo: jnp.ndarray | None = None,
                 k_scale: jnp.ndarray | None = None,
                 v_scale: jnp.ndarray | None = None, *,
                 bt: int = 128, bs: int = 128,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Blocked online-softmax attention: q (B, T, H, D) against k/v
    (B, S, KV, D) (fp or int8 + per-token (B, S) scales), query ``t`` of
    row ``b`` seeing key positions ``lo[b, t] <= p < hi[b, t]`` (``lo``
    defaults to 0). Returns (B, T, H, D) in q's dtype."""
    if interpret is None:
        interpret = not on_tpu()
    b, t, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = (q * (d ** -0.5)).reshape(b, t, kv, g, d)
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.int32), (b, t))
    if lo is None:
        lo = jnp.zeros((b, t), jnp.int32)
    else:
        lo = jnp.broadcast_to(jnp.asarray(lo, jnp.int32), (b, t))
    out = attn_prefill_pallas(qg, k, v, lo, hi, k_scale, v_scale,
                              bt=bt, bs=bs, interpret=interpret)
    return out.reshape(b, t, h, d)
