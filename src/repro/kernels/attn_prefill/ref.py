"""Pure-jnp oracle for the blocked prefill/verify attention kernel.

Numerically what kernel.py computes, written as one dense einsum so tests
can diff the two: fp32 scores, per-query [lo, hi) masking, guarded softmax
(rows with an empty visible range produce zeros, not NaN or the uniform
average), per-token int8 scale factoring in the exact same places (k_scale
into the scores after QK^T, v_scale into the probabilities before PV).

This IS the old einsum formulation the kernel replaces — it materializes
the full (B, KV, G, T, S) score tensor, which is the point: ref.py is the
parity oracle and the memory baseline, never the serving path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.attn_prefill.kernel import NEG_INF

__all__ = ["attn_prefill_ref"]


def attn_prefill_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lo: jnp.ndarray, hi: jnp.ndarray,
                     k_scale: jnp.ndarray | None = None,
                     v_scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """q (B, T, KV, G, D) PRE-SCALED by 1/sqrt(D); k/v (B, S, KV, D);
    lo/hi (B, T) int32; optional (B, S) fp32 per-token scales. Returns
    (B, T, KV, G, D) in q's dtype."""
    b, t, kv, g, d = q.shape
    s = k.shape[1]
    lo = jnp.broadcast_to(jnp.asarray(lo, jnp.int32), (b, t))
    hi = jnp.broadcast_to(jnp.asarray(hi, jnp.int32), (b, t))
    kf = k.astype(q.dtype)
    sc = jnp.einsum("btkgd,bskd->bkgts", q, kf,
                    preferred_element_type=jnp.float32)
    if k_scale is not None:
        sc = sc * k_scale.astype(jnp.float32)[:, None, None, None, :]
    pos = jnp.arange(s, dtype=jnp.int32)
    valid = ((pos[None, None, :] < hi[:, :, None])
             & (pos[None, None, :] >= lo[:, :, None]))       # (B, T, S)
    sc = jnp.where(valid[:, None, None], sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    p = jnp.where(m > NEG_INF / 2, jnp.exp(sc - m), 0.0)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    vf = v.astype(q.dtype)
    if v_scale is not None:
        p = (p * v_scale.astype(jnp.float32)[:, None, None, None, :]
             ).astype(q.dtype)
    else:
        p = p.astype(q.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", p, vf,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
