"""Jit'd wrapper for the packed decode matvec (used by quant_dense.packed_apply)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qmatvec.kernel import qmatvec_pallas
from repro.kernels.qmatvec.ref import qmatvec_ref

__all__ = ["qmatvec"]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def qmatvec(x: jnp.ndarray, w_packed: jnp.ndarray, delta: jnp.ndarray, *,
            k: int, interpret: bool | None = None) -> jnp.ndarray:
    """(..., K) against container-packed (KP, N) weights -> (..., N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    out = qmatvec_pallas(x2, w_packed, delta, interpret=interpret)
    return out.reshape(*lead, w_packed.shape[-1])
