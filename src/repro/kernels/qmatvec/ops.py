"""Jit'd wrapper for the packed-container matmul kernel.

Handles leading batch dims and interpret-mode fallback on CPU. Used by the
``quant_dense.serve_apply`` kernel dispatch for the ``qp`` weight form (both
batched decode ``(B<=slots, K)`` and bucketed prefill
``(slots*bucket_len, K)`` shapes) and by the legacy MLP ``packed_apply``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qmatvec.kernel import qmatvec_pallas

__all__ = ["qmatvec"]


@functools.partial(jax.jit, static_argnames=("k", "interpret", "out_dtype"))
def qmatvec(x: jnp.ndarray, w_packed: jnp.ndarray, delta: jnp.ndarray, *,
            k: int, bias: jnp.ndarray | None = None,
            interpret: bool | None = None, out_dtype=None) -> jnp.ndarray:
    """(..., K) against container-packed (KP, N) weights -> (..., N).

    ``bias`` (N,) is fused into the kernel epilogue (applied after the
    per-channel delta rescale, in fp32); ``out_dtype`` overrides the output
    dtype (one cast from the fp32 accumulator)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    out = qmatvec_pallas(x2, w_packed, delta, bias, out_dtype=out_dtype,
                         interpret=interpret)
    return out.reshape(*lead, w_packed.shape[-1])
