"""Pure-jnp oracle for the packed-container decode matvec."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import unpack_matrix

__all__ = ["qmatvec_ref"]


def qmatvec_ref(x: jnp.ndarray, w_packed: jnp.ndarray, delta: jnp.ndarray,
                k: int, bits: int = 3, out_dtype=None) -> jnp.ndarray:
    """x (B, K) @ unpack(w_packed (ceil(K/f), N)) * delta -> (B, N)."""
    out_dtype = out_dtype or x.dtype
    w = unpack_matrix(w_packed, k, bits).astype(jnp.float32)
    acc = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    return (acc * jnp.asarray(delta, jnp.float32)).astype(out_dtype)
