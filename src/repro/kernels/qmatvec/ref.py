"""Pure-jnp oracle for the packed-container decode matvec."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import unpack_matrix

__all__ = ["qmatvec_ref"]


def qmatvec_ref(x: jnp.ndarray, w_packed: jnp.ndarray, delta: jnp.ndarray,
                k: int, bias: jnp.ndarray | None = None, bits: int = 3,
                out_dtype=None) -> jnp.ndarray:
    """x (B, K) @ unpack(w_packed (ceil(K/f), N)) * delta [+ bias] -> (B, N).

    Matches the kernel's numerics: fp32 accumulate, delta (and the optional
    fused bias) applied in fp32 at the end.
    """
    out_dtype = out_dtype or x.dtype
    w = unpack_matrix(w_packed, k, bits).astype(x.dtype)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc * jnp.asarray(delta, jnp.float32)
    if bias is not None:
        acc = acc + jnp.asarray(bias, jnp.float32)
    return acc.astype(out_dtype)
