"""Pallas TPU kernel: decode/prefill matmul streaming 3.2-bit packed weights.

THE paper's regime on TPU (DESIGN §3): decode GEMMs have arithmetic intensity
~1 FLOP/byte, entirely HBM-bandwidth-bound. This kernel streams the weight
matrix in the *container* format — 10 3-bit fields per int32 word, exactly the
paper's BRAM image — so HBM traffic is 3.2 bits/weight instead of 16 (bf16):
a 5x cut of the dominant roofline term. The unpack (shift/mask/sign-extend on
the VPU) is free: the kernel is still bandwidth-bound after a 5x traffic cut.

Layout: words (KP, N) int32 where word j of column n holds weights
k = 10j..10j+9 (packed along K, see core.packing.pack_matrix). The kernel
unpacks a (bkp, bn) word tile to a (10*bkp, bn) level tile in VMEM, converts
to the activation dtype, and MXU-accumulates against the (bm, 10*bkp)
activation slice. fp32 accumulator in VMEM scratch across the KP grid; the
epilogue applies the per-channel delta and the (optional, fused) bias.

The grid covers M too: the same kernel serves batched decode (M = active
slots) and bucketed prefill (M = slots x bucket_len) — weight words stream
once per M-tile regardless of how many rows ride in it, which is the paper's
batch-amortization argument verbatim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax releases (TPUCompilerParams <= 0.4.x < CompilerParams)
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["qmatvec_pallas", "FIELDS"]

FIELDS = 10  # 3-bit fields per int32 container word
_BITS = 3
_MASK = (1 << _BITS) - 1
_SIGN = 1 << (_BITS - 1)


def _unpack_tile(words: jnp.ndarray) -> jnp.ndarray:
    """(bkp, bn) int32 -> (bkp*10, bn) int32 signed levels."""
    bkp, bn = words.shape
    fields = []
    for i in range(FIELDS):
        f = (words >> (i * _BITS)) & _MASK
        fields.append(f - ((f & _SIGN) << 1))      # sign-extend 3-bit
    lv = jnp.stack(fields, axis=1)                 # (bkp, 10, bn)
    return lv.reshape(bkp * FIELDS, bn)


def _kernel(x_ref, w_ref, d_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    lv = _unpack_tile(w_ref[...]).astype(x.dtype)
    acc_ref[...] += jnp.dot(x, lv, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * d_ref[...].astype(jnp.float32)
                      + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bkp", "interpret",
                                             "out_dtype"))
def qmatvec_pallas(x: jnp.ndarray, w_packed: jnp.ndarray, delta: jnp.ndarray,
                   bias: jnp.ndarray | None = None, *, bm: int = 256,
                   bn: int = 256, bkp: int = 128, out_dtype=None,
                   interpret: bool = False) -> jnp.ndarray:
    """x (M, K), w_packed (KP, N) int32, delta (N,), bias (N,)|None -> (M, N).

    K must satisfy KP = ceil(K/10); x is zero-padded to 10*KP internally.
    """
    m, k = x.shape
    kp, n = w_packed.shape
    assert kp * FIELDS >= k, (x.shape, w_packed.shape)
    out_dtype = out_dtype or x.dtype
    delta = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n,))
    bias = (jnp.zeros((n,), jnp.float32) if bias is None
            else jnp.broadcast_to(jnp.asarray(bias, jnp.float32), (n,)))

    bm = min(bm, m)
    bn = min(bn, n)
    bkp = min(bkp, kp)
    mpad = -(-m // bm) * bm
    npad = -(-n // bn) * bn
    kppad = -(-kp // bkp) * bkp
    if npad != n:
        w_packed = jnp.pad(w_packed, ((0, 0), (0, npad - n)))
        delta = jnp.pad(delta, (0, npad - n))
        bias = jnp.pad(bias, (0, npad - n))
    if kppad != kp:
        w_packed = jnp.pad(w_packed, ((0, kppad - kp), (0, 0)))
    xk = kppad * FIELDS
    x = jnp.pad(x, ((0, mpad - m), (0, xk - k)))

    grid = (mpad // bm, npad // bn, kppad // bkp)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bkp * FIELDS), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bkp, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mpad, npad), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, delta, bias)
    return out[:m, :n]
