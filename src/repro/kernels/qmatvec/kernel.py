"""Pallas TPU kernel: decode-time matvec streaming 3.2-bit packed weights.

THE paper's regime on TPU (DESIGN §3): decode GEMMs have arithmetic intensity
~1 FLOP/byte, entirely HBM-bandwidth-bound. This kernel streams the weight
matrix in the *container* format — 10 3-bit fields per int32 word, exactly the
paper's BRAM image — so HBM traffic is 3.2 bits/weight instead of 16 (bf16):
a 5x cut of the dominant roofline term. The unpack (shift/mask/sign-extend on
the VPU) is free: the kernel is still bandwidth-bound after a 5x traffic cut.

Layout: words (KP, N) int32 where word j of column n holds weights
k = 10j..10j+9 (packed along K, see core.packing.pack_matrix). The kernel
unpacks a (bkp, bn) word tile to a (10*bkp, bn) level tile in VMEM, converts
to the activation dtype, and MXU-accumulates against the (B, 10*bkp)
activation slice. fp32 accumulator in VMEM scratch across the KP grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax releases (TPUCompilerParams <= 0.4.x < CompilerParams)
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["qmatvec_pallas", "FIELDS"]

FIELDS = 10  # 3-bit fields per int32 container word
_BITS = 3
_MASK = (1 << _BITS) - 1
_SIGN = 1 << (_BITS - 1)


def _unpack_tile(words: jnp.ndarray) -> jnp.ndarray:
    """(bkp, bn) int32 -> (bkp*10, bn) int32 signed levels."""
    bkp, bn = words.shape
    fields = []
    for i in range(FIELDS):
        f = (words >> (i * _BITS)) & _MASK
        fields.append(f - ((f & _SIGN) << 1))      # sign-extend 3-bit
    lv = jnp.stack(fields, axis=1)                 # (bkp, 10, bn)
    return lv.reshape(bkp * FIELDS, bn)


def _kernel(x_ref, w_ref, d_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    lv = _unpack_tile(w_ref[...]).astype(x.dtype)
    acc_ref[...] += jnp.dot(x, lv, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * d_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bkp", "interpret",
                                             "out_dtype"))
def qmatvec_pallas(x: jnp.ndarray, w_packed: jnp.ndarray, delta: jnp.ndarray,
                   *, bn: int = 256, bkp: int = 128, out_dtype=None,
                   interpret: bool = False) -> jnp.ndarray:
    """x (B, K), w_packed (KP, N) int32, delta (N,) -> (B, N).

    K must satisfy KP = ceil(K/10); x is zero-padded to 10*KP internally.
    """
    b, k = x.shape
    kp, n = w_packed.shape
    assert kp * FIELDS >= k, (x.shape, w_packed.shape)
    out_dtype = out_dtype or x.dtype
    delta = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n,))

    bn = min(bn, n)
    bkp = min(bkp, kp)
    npad = -(-n // bn) * bn
    kppad = -(-kp // bkp) * bkp
    if npad != n:
        w_packed = jnp.pad(w_packed, ((0, 0), (0, npad - n)))
        delta = jnp.pad(delta, (0, npad - n))
    if kppad != kp:
        w_packed = jnp.pad(w_packed, ((0, kppad - kp), (0, 0)))
    xk = kppad * FIELDS
    x = jnp.pad(x, ((0, 0), (0, xk - k)))

    grid = (npad // bn, kppad // bkp)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bkp * FIELDS), lambda j, kk: (0, kk)),
            pl.BlockSpec((bkp, bn), lambda j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((b, bn), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, npad), out_dtype),
        scratch_shapes=[pltpu.VMEM((b, bn), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, delta)
    return out[:, :n]
