"""Jit'd wrapper for the fused decode-attention kernel.

Takes the model-side decode shapes (q (B, 1, H, D) against a (B, S, KV, D)
cache, scalar or per-row ``cache_len``, optional (B, S) int8-cache scales),
handles the GQA reshape + 1/sqrt(D) pre-scale, and falls back to interpret
mode off-TPU (slow; for tests). Used by the ``models.attention``
``decode_attention(..., mode="kernel")`` dispatch — the decode-side
counterpart of ``quant_dense.serve_apply``'s qmatvec/qmatmul routing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attn_decode.kernel import attn_decode_pallas

__all__ = ["attn_decode"]


@functools.partial(jax.jit, static_argnames=("bm", "bs", "interpret"))
def attn_decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                cache_len, k_scale: jnp.ndarray | None = None,
                v_scale: jnp.ndarray | None = None, *, bm: int = 8,
                bs: int = 128,
                interpret: bool | None = None) -> jnp.ndarray:
    """Fused one-token GQA attention: q (B, 1, H, D) x cache (B, S, KV, D)
    -> (B, 1, H, D). ``cache_len`` scalar or (B,); pass per-token
    ``k_scale``/``v_scale`` (B, S) to read an int8 cache directly.

    ``bm`` batch rows ride per program (M-blocking over the engine's slot
    dimension); ``bs`` is the cache block — the score tile never exceeds
    (bm, G, bs) and never leaves VMEM.
    """
    if interpret is None:
        from repro.kernels.qmatmul.ops import on_tpu
        interpret = not on_tpu()
    b, _, h, d = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / (d ** 0.5)
    q4 = (q * scale).reshape(b, kv, g, d)
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))
    out = attn_decode_pallas(q4, k_cache, v_cache, lens, k_scale, v_scale,
                             bm=bm, bs=bs, interpret=interpret)
    return out.reshape(b, 1, h, d)
