"""Pallas TPU kernel: fused single-token GQA decode attention over a
(B, S, KV, D) cache — bf16/f32 or int8 with per-token scales.

Decode attention is the paper's memory-bound regime applied to the KV cache:
per generated token the whole valid cache is read once and O(S*D) FLOPs are
spent on it (~1 FLOP/byte), so decode speed is cache bandwidth. The plain
``decode_attention`` einsum path (models/attention.py) pays that bill three
times over: it materializes a full fp32 (B, KV, G, 1, S) score tensor in
HBM between QK^T, softmax and PV, and it streams all S ring slots no matter
how short each row's valid prefix is. This kernel is the decode-side analog
of the paper's on-chip dataflow (weights/scores never leave the chip):

  * QK^T -> online softmax -> PV fused in VMEM: the (..., S) score tensor
    exists only one (bm, G, bs) tile at a time; the running (m, l, acc)
    flash-attention carry lives in VMEM scratch across the S grid.
  * S-blocked grid with per-row ``cache_len`` masking; blocks that are
    fully past every row's valid length are SKIPPED — the scalar-prefetched
    per-block max length clamps the K/V index map, so Pallas's pipeline
    re-targets the previous block (same index => no new HBM->VMEM copy)
    and ``pl.when`` skips the compute.
  * Fused dequant epilogue: an int8 cache is read directly; per-token
    scales factor through the contractions exactly as in
    ``decode_attention`` (scores * k_scale after QK^T, p * v_scale before
    PV), halving cache bytes vs bf16 — the engine's ``kv_bits=8`` mode.
  * M-blocking over the batch: ``bm`` slot rows ride per program, so the
    engine's batched-slots decode shape (B = slots) runs as one batched
    dot_general per (M-block, kv-head, S-block).

Grid: (B/bm, KV, S/bs), S innermost ("arbitrary" — sequential accumulation
into the scratch carry); B and KV are parallel. One q block is (bm, G, D)
for a single kv head (GQA group G = H // KV), K/V blocks are (bm, bs, D).

Numerics match ``attn_decode_ref`` (ref.py): fp32 scores and softmax
statistics, probabilities cast to the compute dtype for PV, fp32
accumulator, one cast to the query dtype at the end. Rows whose
``cache_len`` is 0 produce zeros (the ref does the same; ``decode_step``
always has cache_len >= 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["attn_decode_pallas", "NEG_INF"]

NEG_INF = -1e30


def _kernel(lmax_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref,
            acc_ref, m_ref, l_ref, *, bs: int, quantized: bool):
    """One (bm, G) q tile against one (bm, bs) cache block.

    Refs: q (bm, 1, G, D); k/v (bm, bs, 1, D); ks/vs (bm, bs) fp32 scales
    (None when not quantized); len (bm, 1) int32; out (bm, 1, G, D).
    Scratch: acc (bm, G, D) fp32; m/l (bm, G) fp32 — the online-softmax
    carry, valid across the innermost S grid dimension.
    """
    i = pl.program_id(0)
    s_blk = pl.program_id(2)
    start = s_blk * bs

    @pl.when(s_blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip blocks past every row's valid length (their K/V DMA was already
    # elided by the clamped index map — see attn_decode_pallas)
    @pl.when(start < lmax_ref[i])
    def _compute():
        q = q_ref[:, 0]                                 # (bm, G, D)
        k = k_ref[:, :, 0]                              # (bm, bs, D)
        sc = jax.lax.dot_general(                       # (bm, G, bs) fp32
            q, k.astype(q.dtype),
            dimension_numbers=(((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        if quantized:
            sc = sc * ks_ref[...].astype(jnp.float32)[:, None, :]
        pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (sc.shape[0], bs), 1)            # (bm, bs)
        valid = pos < len_ref[...]                      # len (bm, 1) bcast
        sc = jnp.where(valid[:, None, :], sc, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        # `alive` guards rows with no valid position yet: m_new == NEG_INF
        # there, and exp(sc - m_new) would be exp(0) = 1 for masked slots
        alive = m_new > NEG_INF / 2
        p = jnp.where(alive[..., None],
                      jnp.exp(sc - m_new[..., None]), 0.0)  # (bm, G, bs)
        corr = jnp.where(alive, jnp.exp(m_prev - m_new), 1.0)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        v = v_ref[:, :, 0]                              # (bm, bs, D)
        if quantized:
            p = (p * vs_ref[...].astype(jnp.float32)[:, None, :]).astype(q.dtype)
            v = v.astype(q.dtype)
        else:
            p = p.astype(v.dtype)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s_blk == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)              # (bm, G)
        o_ref[...] = (acc_ref[...] / l[..., None])[:, None].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bs", "interpret"))
def attn_decode_pallas(q: jnp.ndarray, k_cache: jnp.ndarray,
                       v_cache: jnp.ndarray, cache_len: jnp.ndarray,
                       k_scale: jnp.ndarray | None = None,
                       v_scale: jnp.ndarray | None = None, *,
                       bm: int = 8, bs: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    """q (B, KV, G, D) PRE-SCALED by 1/sqrt(D); k/v cache (B, S, KV, D);
    cache_len (B,) int32; optional per-token scales (B, S) fp32 for an int8
    cache. Returns (B, KV, G, D) in q's dtype.

    ``bm`` rows x ``bs`` cache positions per program; both are clamped and
    the inputs zero-padded, with padded rows masked via cache_len = 0.
    """
    b, kv, g, d = q.shape
    s = k_cache.shape[1]
    quantized = k_scale is not None
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (b,))

    bm = min(bm, b)
    bs = min(bs, s)
    bp = -(-b // bm) * bm
    sp = -(-s // bs) * bs
    if bp != b:
        q = jnp.pad(q, ((0, bp - b),) + ((0, 0),) * 3)
        k_cache = jnp.pad(k_cache, ((0, bp - b),) + ((0, 0),) * 3)
        v_cache = jnp.pad(v_cache, ((0, bp - b),) + ((0, 0),) * 3)
        lens = jnp.pad(lens, (0, bp - b))               # pad rows: len 0
    if sp != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    if quantized:
        k_scale = jnp.pad(jnp.asarray(k_scale, jnp.float32),
                          ((0, bp - b), (0, sp - s)))
        v_scale = jnp.pad(jnp.asarray(v_scale, jnp.float32),
                          ((0, bp - b), (0, sp - s)))
    nb, ns = bp // bm, sp // bs
    # per-M-block max valid length, scalar-prefetched: the index maps clamp
    # the S block index with it, so fully-invalid blocks re-target the last
    # valid block — same index as the previous grid step => the pipeline
    # skips the HBM->VMEM copy (the "don't stream the whole ring" part)
    lmax = jnp.max(lens.reshape(nb, bm), axis=1)
    len2 = lens[:, None]

    def kv_idx(i, j, s_blk, lmax_ref):
        nblk = jnp.maximum((lmax_ref[i] + bs - 1) // bs, 1)
        return (i, jnp.minimum(s_blk, nblk - 1), j, 0)

    def sc_idx(i, j, s_blk, lmax_ref):
        nblk = jnp.maximum((lmax_ref[i] + bs - 1) // bs, 1)
        return (i, jnp.minimum(s_blk, nblk - 1))

    in_specs = [
        pl.BlockSpec((bm, 1, g, d), lambda i, j, s_blk, lmax: (i, j, 0, 0)),
        pl.BlockSpec((bm, bs, 1, d), kv_idx),
        pl.BlockSpec((bm, bs, 1, d), kv_idx),
    ]
    args = [q, k_cache, v_cache]
    if quantized:
        in_specs += [pl.BlockSpec((bm, bs), sc_idx),
                     pl.BlockSpec((bm, bs), sc_idx)]
        args += [k_scale, v_scale]
    in_specs.append(
        pl.BlockSpec((bm, 1), lambda i, j, s_blk, lmax: (i, 0)))
    args.append(len2)

    if quantized:
        kernel = functools.partial(_kernel, bs=bs, quantized=True)
    else:                  # no scale operands: splice None refs back in
        def kernel(lmax_ref, q_ref, k_ref, v_ref, len_ref, o_ref,
                   acc_ref, m_ref, l_ref):
            return _kernel(lmax_ref, q_ref, k_ref, v_ref, None, None,
                           len_ref, o_ref, acc_ref, m_ref, l_ref,
                           bs=bs, quantized=False)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, kv, ns),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, 1, g, d),
                               lambda i, j, s_blk, lmax: (i, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bm, g, d), jnp.float32),        # acc
            pltpu.VMEM((bm, g), jnp.float32),           # running max
            pltpu.VMEM((bm, g), jnp.float32),           # running sum
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bp, kv, g, d), q.dtype),
        interpret=interpret,
    )(lmax, *args)
    return out[:b]
