"""Pure-jnp oracle for the fused decode-attention kernel.

Same contract and numerics class as ``kernel.attn_decode_pallas``: fp32
scores/softmax statistics, per-token int8 scales factored exactly where the
kernel applies them (k_scale after QK^T, v_scale into the probabilities
before PV), probabilities cast to the compute dtype for the PV contraction,
one cast back to the query dtype. Rows with ``cache_len == 0`` return zeros
(the kernel's guard; a plain softmax would return the uniform average).

In exact arithmetic this equals ``models.attention.decode_attention``
whenever every row has ``cache_len >= 1`` — which ``decode_step`` always
guarantees — so the kernel is cross-checked against both (tests +
``benchmarks/kernels_bench.py`` parity gate).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.attn_decode.kernel import NEG_INF

__all__ = ["attn_decode_ref"]


def attn_decode_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, cache_len,
                    k_scale: jnp.ndarray | None = None,
                    v_scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """q (B, 1, H, D); k/v cache (B, S, KV, D); cache_len scalar or (B,);
    optional (B, S) per-token scales for an int8 cache -> (B, 1, H, D)."""
    b, _, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = 1.0 / (d ** 0.5)
    qr = (q * scale).reshape(b, 1, kvh, g, d)
    kc = k_cache if k_scale is None else k_cache.astype(q.dtype)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qr, kc,
                    preferred_element_type=jnp.float32)
    if k_scale is not None:
        sc = sc * k_scale[:, None, None, None, :].astype(jnp.float32)
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.broadcast_to(
        jnp.asarray(cache_len)[..., None], (b, s))
    sc = jnp.where(valid[:, None, None, None], sc, NEG_INF)
    m = jnp.max(sc, axis=-1, keepdims=True)
    # masked exp with the kernel's empty-row guard: all-invalid rows get
    # p == 0 everywhere (not the uniform average a raw softmax would give)
    p = jnp.where(m > NEG_INF / 2, jnp.exp(sc - m), 0.0)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    if v_scale is not None:
        p = (p * v_scale[:, None, None, None, :].astype(jnp.float32)
             ).astype(q.dtype)
        vc = v_cache.astype(q.dtype)
    else:
        p = p.astype(v_cache.dtype)
        vc = v_cache
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vc,
                     preferred_element_type=jnp.float32)
    out = out / l.transpose(0, 3, 1, 2, 4).astype(jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)
