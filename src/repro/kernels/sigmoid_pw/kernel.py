"""Pallas elementwise kernel for the piecewise-linear sigmoid.

On the FPGA this is combinational logic between tiles (paper §3); on TPU it
is a VPU-only elementwise op fused over VMEM tiles — included for paper
fidelity and as the activation epilogue of the quantized MLP path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sigmoid_pw.ref import sigmoid_pw as _pw

__all__ = ["sigmoid_pw_pallas"]

_LANES = 128
_SUBLANES = 8


def _kernel(x_ref, o_ref):
    o_ref[...] = _pw(x_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def sigmoid_pw_pallas(x: jnp.ndarray, interpret: bool = False) -> jnp.ndarray:
    shape = x.shape
    n = x.size
    cols = _LANES
    rows = -(-n // cols)
    rows_pad = -(-rows // _SUBLANES) * _SUBLANES
    xf = jnp.pad(x.reshape(-1), (0, rows_pad * cols - n)).reshape(rows_pad, cols)
    block_r = min(rows_pad, 512)
    grid = (rows_pad // block_r,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_r, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_r, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, cols), x.dtype),
        interpret=interpret,
    )(xf)
    return out.reshape(-1)[:n].reshape(shape)
