"""Piecewise-linear sigmoid (paper §3, ref [16]) — jnp oracle.

The paper implements the sigmoid as minimized combinational logic on 8-bit
signals. The classic PLAN approximation (Amin, Curtis & Hayes-Gill 1997 — the
same family of hardware-friendly piecewise fits as Tommiska's [16] SOP form)
uses power-of-two slopes so hardware needs only shifts:

    y(|x|) = 1                      |x| >= 5
           = 0.03125|x| + 0.84375   2.375 <= |x| < 5
           = 0.125 |x| + 0.625      1     <= |x| < 2.375
           = 0.25  |x| + 0.5        0     <= |x| < 1
    y(-x)  = 1 - y(x)

Max abs error vs exact sigmoid: 0.0189 — below the paper's 8-bit signal
quantum tolerance context (1/256 ~ 0.0039 per level, error spans ~5 levels,
matching the fidelity class of [16]).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["sigmoid_pw"]


def sigmoid_pw(x: jnp.ndarray) -> jnp.ndarray:
    xf = jnp.abs(x.astype(jnp.float32))
    y = jnp.where(
        xf >= 5.0, 1.0,
        jnp.where(xf >= 2.375, 0.03125 * xf + 0.84375,
                  jnp.where(xf >= 1.0, 0.125 * xf + 0.625,
                            0.25 * xf + 0.5)))
    y = jnp.where(x < 0, 1.0 - y, y)
    return y.astype(x.dtype)
