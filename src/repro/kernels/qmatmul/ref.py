"""Pure-jnp oracle for the levels-form W3 matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["qmatmul_ref"]


def qmatmul_ref(x: jnp.ndarray, w_q: jnp.ndarray, delta: jnp.ndarray,
                bias: jnp.ndarray | None = None,
                out_dtype=None) -> jnp.ndarray:
    """x (M, K) @ dequant(w_q (K, N) int8 levels, delta (N,) or scalar).

    Matches the kernel's numerics: fp32 accumulate, delta (and the optional
    fused bias) applied in fp32 at the end.
    """
    out_dtype = out_dtype or x.dtype
    acc = jnp.dot(x.astype(jnp.float32), w_q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    acc = acc * jnp.asarray(delta, jnp.float32)
    if bias is not None:
        acc = acc + jnp.asarray(bias, jnp.float32)
    return acc.astype(out_dtype)
