"""Jit'd public wrapper for the levels-form (int8) W3 matmul kernel.

Handles leading batch dims, interpret-mode fallback on CPU (the container
runtime), and block-size selection. Serves the ``q`` weight form in the
``quant_dense.serve_apply`` kernel dispatch — batched decode ``(B, K)`` and
bucketed prefill ``(slots*bucket_len, K)`` shapes alike — with the bias
fused into the kernel epilogue. ``qdense``: full quantized dense layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qmatmul.kernel import qmatmul_pallas

__all__ = ["qmatmul", "qdense", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_blocks(m: int, n: int, k: int):
    """MXU-aligned blocks sized for ~1.5MB VMEM working set."""
    bm = 256 if m >= 256 else max(8, m)
    bn = 512 if n >= 512 else max(128, min(n, 512))
    bk = 512 if k >= 512 else max(128, min(k, 512))
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def qmatmul(x: jnp.ndarray, w_q: jnp.ndarray, delta: jnp.ndarray,
            bias: jnp.ndarray | None = None,
            interpret: bool | None = None, out_dtype=None) -> jnp.ndarray:
    """(..., K) x (K, N) int8 levels -> (..., N); delta (N,) or scalar.

    ``bias`` (N,) is fused into the kernel epilogue (after the delta
    rescale, in fp32). ``out_dtype`` overrides the output dtype (the fp32
    accumulator is cast once, in the epilogue — e.g. fp32 logits from bf16
    activations)."""
    if interpret is None:
        interpret = not on_tpu()
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w_q.shape[-1]
    x2 = x.reshape(-1, k)
    bm, bn, bk = pick_blocks(x2.shape[0], n, k)
    out = qmatmul_pallas(x2, w_q, delta, bias, bm=bm, bn=bn, bk=bk,
                         out_dtype=out_dtype, interpret=interpret)
    return out.reshape(*lead, n)


def qdense(x: jnp.ndarray, w_q: jnp.ndarray, delta: jnp.ndarray,
           bias: jnp.ndarray | None = None, interpret: bool | None = None):
    """Quantized dense layer: kernel matmul with the bias fused in."""
    return qmatmul(x, w_q, delta, bias, interpret=interpret)
