"""Jit'd public wrapper for the packed-W3 matmul kernel.

Handles leading batch dims, interpret-mode fallback on CPU (the container
runtime), and block-size selection. ``qdense``: full quantized dense layer
(kernel matmul + bias).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qmatmul.kernel import qmatmul_pallas
from repro.kernels.qmatmul.ref import qmatmul_ref

__all__ = ["qmatmul", "qdense", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_blocks(m: int, n: int, k: int):
    """MXU-aligned blocks sized for ~1.5MB VMEM working set."""
    bm = 256 if m >= 256 else max(8, m)
    bn = 512 if n >= 512 else max(128, min(n, 512))
    bk = 512 if k >= 512 else max(128, min(k, 512))
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("interpret",))
def qmatmul(x: jnp.ndarray, w_q: jnp.ndarray, delta: jnp.ndarray,
            interpret: bool | None = None) -> jnp.ndarray:
    """(..., K) x (K, N) int8 levels -> (..., N); delta (N,) or scalar."""
    if interpret is None:
        interpret = not on_tpu()
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w_q.shape[-1]
    x2 = x.reshape(-1, k)
    bm, bn, bk = pick_blocks(x2.shape[0], n, k)
    out = qmatmul_pallas(x2, w_q, delta, bm=bm, bn=bn, bk=bk,
                         interpret=interpret)
    return out.reshape(*lead, n)


def qdense(x: jnp.ndarray, w_q: jnp.ndarray, delta: jnp.ndarray,
           bias: jnp.ndarray | None = None, interpret: bool | None = None):
    y = qmatmul(x, w_q, delta, interpret=interpret)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y
