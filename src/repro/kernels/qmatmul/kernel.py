"""Pallas TPU kernel: X @ dequant(W3) with on-chip (VMEM) dequantization.

The paper's insight mapped to the MXU (DESIGN §2): the weight matrix is
streamed HBM→VMEM as int8 *levels* (the paper's {-3..3} codes — half the
bytes of bf16), converted to bf16 inside VMEM (VPU convert, hidden behind the
MXU pipeline), matmul'd on the MXU with fp32 accumulation across the K grid,
and rescaled by the per-channel step size delta in the epilogue — exactly the
paper's PU accumulate-then-Delta-rescale dataflow (Fig. 4), retargeted.

Grid: (M/bm, N/bn, K/bk), K innermost ("arbitrary" semantics — sequential),
fp32 accumulator lives in a VMEM scratch tile, initialized at k==0 and
flushed (delta-scaled) at the last k step.

Block defaults (bm=256, bk=512, bn=512) keep the working set
256KB(x) + 256KB(w) + 512KB(acc) + 512KB(out) << 16MB v5e VMEM, and every
MXU dim is a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax releases (TPUCompilerParams <= 0.4.x < CompilerParams)
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["qmatmul_pallas"]


def _kernel(x_ref, w_ref, d_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...].astype(x.dtype)          # int8 levels -> compute dtype, in VMEM
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * d_ref[...].astype(jnp.float32)
                      + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret",
                                             "out_dtype"))
def qmatmul_pallas(x: jnp.ndarray, w_q: jnp.ndarray, delta: jnp.ndarray,
                   bias: jnp.ndarray | None = None, *,
                   bm: int = 256, bn: int = 512, bk: int = 512,
                   out_dtype=None, interpret: bool = False) -> jnp.ndarray:
    """x (M, K) x w_q (K, N) int8 levels x delta (N,) [+ bias (N,)] -> (M, N)."""
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2, (x.shape, w_q.shape)
    delta = jnp.broadcast_to(jnp.asarray(delta, jnp.float32), (n,))
    bias = (jnp.zeros((n,), jnp.float32) if bias is None
            else jnp.broadcast_to(jnp.asarray(bias, jnp.float32), (n,)))
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    # pad to block multiples (zeros contribute nothing to the accumulation)
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w_q = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
    if np_ != n:
        delta = jnp.pad(delta, (0, np_ - n))
        bias = jnp.pad(bias, (0, np_ - n))

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_q, delta, bias)
    return out[:m, :n]
