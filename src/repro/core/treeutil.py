"""Small pytree helpers used across the framework (pure-dict param trees)."""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "map_with_path", "flatten_with_path", "unflatten", "tree_size",
    "tree_nbytes", "tree_get", "tree_set", "role_of", "any_nan",
]


def map_with_path(fn: Callable[[str, Any], Any], tree: Any, _prefix: str = "") -> Any:
    """Map ``fn(path, leaf)`` over a nested-dict tree; preserves structure.

    ``None`` leaves map to ``None`` (used as "not quantized" sentinels in
    delta trees).
    """
    if isinstance(tree, dict):
        return {k: map_with_path(fn, v, f"{_prefix}/{k}" if _prefix else k)
                for k, v in tree.items()}
    if tree is None:
        return None
    return fn(_prefix, tree)


def flatten_with_path(tree: Any, _prefix: str = "") -> Dict[str, Any]:
    """Flatten a nested-dict tree into {path: leaf} (skips None leaves)."""
    out: Dict[str, Any] = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(flatten_with_path(v, f"{_prefix}/{k}" if _prefix else k))
    elif tree is not None:
        out[_prefix] = tree
    return out


def unflatten(flat: Dict[str, Any]) -> Any:
    """Inverse of :func:`flatten_with_path`."""
    tree: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


def tree_get(tree: Any, path: str) -> Any:
    """Leaf at a ``flatten_with_path``-style '/'-joined path. KeyError names
    the missing path segment."""
    node = tree
    for p in path.split("/"):
        if not isinstance(node, dict) or p not in node:
            raise KeyError(f"no leaf at {path!r} (missing {p!r})")
        node = node[p]
    return node


def tree_set(tree: Any, path: str, value: Any) -> Any:
    """Functional single-leaf update: a new tree with ``path`` replaced by
    ``value``. Only the dicts along the path are copied (siblings shared),
    so swapping one healed container never duplicates the rest of the
    params. The path must already exist (this repairs leaves, it does not
    grow trees)."""
    parts = path.split("/")
    tree_get(tree, path)                      # validate before copying
    out = dict(tree)
    node = out
    for p in parts[:-1]:
        node[p] = dict(node[p])
        node = node[p]
    node[parts[-1]] = value
    return out


def tree_size(tree: Any) -> int:
    """Total number of elements across all array leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_nbytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


# --- role inference from parameter path (see precision.py role table) --------

_OUTPUT_MARKERS = ("head", "unembed", "logits", "w_out_layer", "output_layer")
_EMBED_MARKERS = ("embed",)
_ROUTER_MARKERS = ("router", "gate_w")
_SSM_MARKERS = ("a_log", "dt_bias", "dt_w", "conv", "ssm_d")
_SKIP_MARKERS = ("norm", "scale", "/b", "bias", "ln_", "rope")


def role_of(path: str) -> str:
    """Infer the quantization role of a weight from its tree path."""
    p = path.lower()
    if any(m in p for m in _SSM_MARKERS):
        return "ssm"
    if any(m in p for m in _SKIP_MARKERS) or p.endswith("/b") or p.endswith("bias"):
        return "bias"
    if any(m in p for m in _ROUTER_MARKERS):
        return "router"
    if any(m in p for m in _OUTPUT_MARKERS):
        return "output"
    if any(m in p for m in _EMBED_MARKERS):
        return "embed"
    return "hidden"


def any_nan(tree: Any) -> bool:
    leaves = [jnp.any(jnp.isnan(x)) for x in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    if not leaves:
        return False
    return bool(jnp.any(jnp.stack(leaves)))
