"""Per-layer quantization policy (paper §2.1: hidden 3-bit, output 8-bit).

A :class:`QuantPolicy` decides, for every weight leaf by *role*, which
:class:`~repro.core.quantizer.QuantSpec` applies (or none). Roles are assigned
by the model code when it calls ``policy.spec_for(role)``:

  role            paper analogue                      default bits
  ------------    --------------------------------    ------------
  hidden          hidden-layer weight matrices        3
  output          output/classifier layer (W8)        8
  embed           embedding tables                    8
  router          MoE router (small & sensitive)      8
  ssm             SSM dynamics (A, dt, conv)          None (fp32)
  norm/bias       norms & biases                      None (fp32)

``mode`` selects the forward-path realization:
  'float'  — no quantization (paper step 1 / GPU baseline)
  'fake'   — STE fake-quant (paper step 3, QAT)
  'packed' — inference with integer levels + delta (paper's deployed form)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.quantizer import QuantSpec

__all__ = ["QuantPolicy", "FLOAT", "W3A8", "W4A8", "W8", "TERNARY"]

_NOQUANT_ROLES = ("norm", "bias", "ssm", "scale")


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Maps weight roles to quant specs; controls forward-path mode."""

    mode: str = "float"                 # 'float' | 'fake' | 'packed'
    bits: Dict[str, Optional[int]] = dataclasses.field(
        default_factory=lambda: {"hidden": 3, "output": 8, "embed": 8, "router": 8}
    )
    act_bits: Optional[int] = None      # None = full precision activations
    per_channel: Optional[int] = None   # None = per-tensor (paper); else axis

    def spec_for(self, role: str) -> Optional[QuantSpec]:
        if self.mode == "float":
            return None
        if role in _NOQUANT_ROLES:
            return None
        b = self.bits.get(role, self.bits.get("hidden"))
        if not b:
            return None
        return QuantSpec(bits=b, per_channel=self.per_channel)

    @property
    def quantized(self) -> bool:
        return self.mode != "float"

    def with_mode(self, mode: str) -> "QuantPolicy":
        return dataclasses.replace(self, mode=mode)


FLOAT = QuantPolicy(mode="float")
# The paper's deployed configuration: 3-bit hidden, 8-bit output, 8-bit signals.
W3A8 = QuantPolicy(mode="fake", bits={"hidden": 3, "output": 8, "embed": 8, "router": 8}, act_bits=8)
W4A8 = QuantPolicy(mode="fake", bits={"hidden": 4, "output": 8, "embed": 8, "router": 8}, act_bits=8)
W8 = QuantPolicy(mode="fake", bits={"hidden": 8, "output": 8, "embed": 8, "router": 8})
# Hwang & Sung 2014 ternary (+1, 0, -1) — the paper's reference [14].
TERNARY = QuantPolicy(mode="fake", bits={"hidden": 2, "output": 8, "embed": 8, "router": 8}, act_bits=8)
