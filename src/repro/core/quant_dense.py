"""Quantizable dense layer — the building block every model in the zoo uses.

A weight leaf takes one of three forms (all flow through the same model code):

  {"w": f32}                   master float weights
                               -> policy 'float': used as-is (GPU baseline)
                               -> policy 'fake':  STE fake-quant (paper step 3)
  {"q": int8, "delta"}         serve form A: quantized *levels* at full shape
                               (the Pallas qmatmul streaming format — 1 B/wt)
  {"qp": int32, "delta"}       serve form B: 3-bit container words packed
                               along K (10 wt/word — the paper's BRAM image,
                               0.4 B/wt HBM traffic; Pallas qmatvec format)

``export_levels`` / ``export_container`` convert a trained tree to the serve
forms (per-output-channel deltas; stacked layer dims handled). Biases stay
full precision per the paper.

Serve-form matmuls route through a unified kernel dispatch
(:func:`serve_apply`) selected by ``mode``:

  'kernel'   Pallas kernels — qmatvec streams ``qp`` containers (0.4 B/wt),
             qmatmul streams ``q`` levels (1 B/wt); the weight is expanded
             only inside VMEM, exactly the paper's expand-at-the-multiplier
             rule. Runs in interpret mode off-TPU (slow; for tests).
  'dequant'  fused fallback: the int levels are cast to the ACTIVATION dtype
             and matmul'd directly, with the per-channel delta applied to
             the (M, N) output — never to the (K, N) weight. No fp32
             dequantized weight matrix exists in the graph; numerics match
             the kernel epilogue (fp32 accumulate, delta+bias at the end).
  'auto'     'kernel' on TPU, 'dequant' elsewhere (the serving default).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import qat
from repro.core import quantizer as qz
from repro.core.precision import QuantPolicy
from repro.core.treeutil import flatten_with_path, map_with_path, role_of, unflatten

__all__ = ["init", "apply", "serve_apply", "tied_logits", "resolve_matmul_mode",
           "MATMUL_MODES", "effective_weight", "fit_deltas", "fit_deltas_stacked",
           "export_levels", "export_container", "export_packed", "packed_apply",
           "is_serve_form"]


def init(key, in_dim: int, out_dim: int, *, bias: bool = True,
         dtype=jnp.float32, scale: Optional[float] = None) -> Dict[str, Any]:
    """He/Glorot-style init. Param names: 'w' (in,out), optional 'b' (out,)."""
    if scale is None:
        scale = 1.0 / jnp.sqrt(in_dim)
    w = jax.random.uniform(key, (in_dim, out_dim), dtype, -1.0, 1.0) * scale
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


MATMUL_MODES = ("auto", "kernel", "dequant")


def resolve_matmul_mode(mode: str) -> str:
    """'auto' -> Pallas kernels on TPU, fused-dequant matmul elsewhere."""
    if mode == "auto":
        from repro.kernels.qmatmul.ops import on_tpu
        return "kernel" if on_tpu() else "dequant"
    if mode not in ("kernel", "dequant"):
        raise ValueError(f"matmul mode must be one of {MATMUL_MODES}, "
                         f"got {mode!r}")
    return mode


def effective_weight(params, policy: QuantPolicy, role: str,
                     delta: Optional[jnp.ndarray] = None,
                     k: Optional[int] = None,
                     dtype=jnp.float32) -> jnp.ndarray:
    """The weight the forward pass sees. ``params``: leaf dict or raw array.

    ``k``: logical reduction dim (required for the "qp" container form —
    callers know it from the activation shape). For the serve forms this
    MATERIALIZES the dequantized matrix at ``dtype`` — it is the reference
    oracle (tests) and the 3D-expert fallback; the serve path itself goes
    through :func:`serve_apply`, which never builds this product."""
    if not isinstance(params, dict):
        params = {"w": params}
    if "qp" in params:
        from repro.core import packing
        assert k is not None, "container form needs the logical K"
        q = packing.unpack_matrix(params["qp"], k, 3)
        return q.astype(dtype) * params["delta"].astype(dtype)
    if "q" in params:
        return params["q"].astype(dtype) * params["delta"].astype(dtype)
    w = params["w"]
    spec = policy.spec_for(role)
    if spec is None:
        return w
    return qat.fake_quant(w, spec, delta)


def serve_apply(params: Dict[str, Any], x: jnp.ndarray, *,
                mode: str = "auto", out_dtype=None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Dense forward for a 2D serve-form leaf ({"q"} or {"qp"}, + "delta",
    optional "b") — the unified kernel dispatch. Never materializes a
    dequantized weight matrix: 'kernel' expands the weight in VMEM (Pallas),
    'dequant' matmuls the raw levels in the activation dtype and applies
    delta/bias to the (M, N) output. Both share the kernel's numerics
    (fp32 accumulate, fp32 epilogue, one cast to ``out_dtype`` — default
    the activation dtype; pass fp32 for precision-sensitive outputs like
    router/logit heads under bf16 activations)."""
    mode = resolve_matmul_mode(mode)
    k = x.shape[-1]
    bias = params.get("b")
    delta = params["delta"].reshape(-1)          # (1, N) -> (N,)
    if mode == "kernel":
        if "qp" in params:
            from repro.kernels.qmatvec import ops as qmv_ops
            return qmv_ops.qmatvec(x, params["qp"], delta, k=k, bias=bias,
                                   out_dtype=out_dtype, interpret=interpret)
        from repro.kernels.qmatmul import ops as qmm_ops
        return qmm_ops.qmatmul(x, params["q"], delta, bias=bias,
                               out_dtype=out_dtype, interpret=interpret)
    if "qp" in params:
        from repro.core import packing
        lv = packing.unpack_matrix(params["qp"], k, 3)
    else:
        lv = params["q"]
    lead = x.shape[:-1]
    acc = jnp.dot(x.reshape(-1, k), lv.astype(x.dtype),
                  preferred_element_type=jnp.float32)
    acc = acc * delta.astype(jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    return acc.astype(out_dtype or x.dtype).reshape(*lead, lv.shape[-1])


def tied_logits(params: Dict[str, Any], h: jnp.ndarray, *,
                mode: str = "auto",
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Tied-embedding readout h @ (q*delta)^T for a serve-form embedding
    table {"q": (V, D), "delta": (1, D)} — without dequantizing the table.
    delta is per-embedding-dim, i.e. per REDUCTION dim of the readout, so it
    rescales the activations instead: (h * delta) @ q^T."""
    mode = resolve_matmul_mode(mode)
    d1 = params["delta"].reshape(-1).astype(jnp.float32)       # (D,)
    hs = (h.astype(jnp.float32) * d1).astype(h.dtype)
    if mode == "kernel":
        from repro.kernels.qmatmul import ops as qmm_ops
        return qmm_ops.qmatmul(hs, params["q"].T, 1.0, interpret=interpret)
    lead = h.shape[:-1]
    acc = jnp.einsum("md,vd->mv", hs.reshape(-1, hs.shape[-1]),
                     params["q"].astype(h.dtype),
                     preferred_element_type=jnp.float32)
    return acc.astype(h.dtype).reshape(*lead, params["q"].shape[0])


def apply(params: Dict[str, Any], x: jnp.ndarray, *, policy: QuantPolicy,
          role: str = "hidden", delta: Optional[jnp.ndarray] = None,
          quantize_input: bool = False, mode: str = "auto",
          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Dense forward under any weight form. Serve forms ({"q"}/{"qp"})
    dispatch through :func:`serve_apply` per ``mode``; float/fake-quant
    master weights take the classic matmul."""
    if not isinstance(params, dict):
        params = {"w": params}
    if quantize_input and policy.act_bits:
        x = qat.fake_quant_act(x, policy.act_bits)
    if "qp" in params or "q" in params:
        return serve_apply(params, x, mode=mode, interpret=interpret)
    w = effective_weight(params, policy, role, delta, k=x.shape[-1])
    y = x @ w.astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# --- whole-tree operations ----------------------------------------------------

def is_serve_form(params: Any) -> bool:
    """True if the tree already carries serve-form leaves ({"q"} levels or
    {"qp"} packed containers) rather than float master weights — i.e.
    ``export_levels``/``export_container`` already ran on it."""
    flat = flatten_with_path(params)
    return any(p == n or p.endswith("/" + n)
               for p in flat for n in ("q", "qp"))


def _is_weight(path: str) -> bool:
    return path.endswith("/w") or path == "w"


def _stacked_dims(path: str) -> int:
    """Leading layer-stack dims for scanned params (layers/ =1, groups/ =2)."""
    if path.startswith("groups/") or "/groups/" in path:
        return 2
    if any(path.startswith(p) or f"/{p}/" in path
           for p in ("layers", "tail")):
        return 1
    return 0


def _leaf_spec(path: str, policy: QuantPolicy) -> Optional[qz.QuantSpec]:
    if not _is_weight(path):
        return None
    return policy.spec_for(role_of(path))


def fit_deltas(params: Any, policy: QuantPolicy) -> Any:
    """Step 2 of the paper (per-tensor, unstacked trees — the MLP repro)."""
    def fit(path, leaf):
        spec = _leaf_spec(path, policy)
        if spec is None:
            return None
        return qz.optimal_uniform_delta(leaf, spec)

    return map_with_path(fit, params)


def fit_deltas_stacked(params: Any, policy: QuantPolicy) -> Any:
    """Per-layer per-tensor deltas for scan-stacked LM trees: a leaf
    (L, ..., N) gets delta (L,) (or (G, A) for hybrid groups) — one step size
    per layer per tensor, the paper's rule applied layerwise."""
    def fit(path, leaf):
        spec = _leaf_spec(path, policy)
        if spec is None:
            return None
        nd = _stacked_dims(path)
        if nd == 0:
            return qz.optimal_uniform_delta(leaf, spec)
        flat = leaf.reshape((-1,) + leaf.shape[nd:])
        ds = jax.vmap(lambda w: qz.optimal_uniform_delta(w, spec))(flat)
        return ds.reshape(leaf.shape[:nd])

    return map_with_path(fit, params)


def _quantize_leaf(leaf: jnp.ndarray, spec: qz.QuantSpec, nd: int):
    """Per-output-channel (last dim) levels+delta, vmapped over stacked dims.
    Returns (q int8 same shape, delta broadcastable against q)."""
    cspec = qz.QuantSpec(bits=spec.bits, per_channel=-1, iters=spec.iters)
    if nd == 0:
        d = qz.optimal_uniform_delta(leaf, cspec)
        q = qz.quantize_levels(leaf, d, cspec)
        shape = [1] * (leaf.ndim - 1) + [leaf.shape[-1]]
        return q, d.reshape(shape)
    flat = leaf.reshape((-1,) + leaf.shape[nd:])
    d = jax.vmap(lambda w: qz.optimal_uniform_delta(w, cspec))(flat)
    q = jax.vmap(lambda w, dd: qz.quantize_levels(w, dd, cspec))(flat, d)
    bshape = leaf.shape[:nd] + (1,) * (leaf.ndim - nd - 1) + (leaf.shape[-1],)
    return q.reshape(leaf.shape), d.reshape(bshape)


def export_levels(params: Any, policy: QuantPolicy) -> Any:
    """Serve form A: every quantizable weight -> {"q": int8, "delta"}."""
    flat = flatten_with_path(params)
    out: Dict[str, Any] = {}
    for path, leaf in flat.items():
        spec = _leaf_spec(path, policy)
        if spec is None:
            out[path] = leaf
            continue
        q, d = _quantize_leaf(leaf, spec, _stacked_dims(path))
        out[path.rsplit("/", 1)[0] + "/q" if "/" in path else "q"] = q
        out[path.rsplit("/", 1)[0] + "/delta" if "/" in path else "delta"] = d
    return unflatten(out)


def export_container(params: Any, policy: QuantPolicy) -> Any:
    """Serve form B: 3-bit roles -> {"qp": int32 containers packed along K,
    "delta"}; other quantized roles (8-bit output/embed) stay form A."""
    from repro.core import packing

    flat = flatten_with_path(params)
    out: Dict[str, Any] = {}
    for path, leaf in flat.items():
        spec = _leaf_spec(path, policy)
        if spec is None:
            out[path] = leaf
            continue
        nd = _stacked_dims(path)
        q, d = _quantize_leaf(leaf, spec, nd)
        base = path.rsplit("/", 1)[0] + "/" if "/" in path else ""
        # container form only for logically-2D weights (K, N); 3D expert
        # tensors keep the level form (their einsum needs the full shape)
        if spec.bits == 3 and leaf.ndim - nd == 2:
            import math
            k = math.prod(leaf.shape[nd:-1])
            q2 = q.reshape(leaf.shape[:nd] + (k, leaf.shape[-1]))
            # range contract must be enforced HERE, on the concrete stacked
            # levels: inside the vmapped pack below they are tracers and
            # pack_matrix's own check cannot see them (out-of-range values
            # would truncate to wrong-but-plausible weights)
            packing._check_levels(q2, 3)
            pack = lambda m: packing.pack_matrix(m, 3)
            for _ in range(nd):
                pack = jax.vmap(pack)
            out[base + "qp"] = pack(q2)
            out[base + "delta"] = d.reshape(
                leaf.shape[:nd] + (1, leaf.shape[-1]))
        else:
            out[base + "q"] = q
            out[base + "delta"] = d
    return unflatten(out)


def export_packed(params: Any, policy: QuantPolicy) -> Any:
    """Legacy MLP-repro container export (per-tensor delta + shape record)."""
    from repro.core import packing

    flat = flatten_with_path(params)
    out: Dict[str, Any] = {}
    for path, leaf in flat.items():
        spec = _leaf_spec(path, policy)
        if spec is None:
            out[path] = leaf
            continue
        q, delta = qz.quantize(leaf, spec)
        q2d = q.reshape(-1, q.shape[-1]) if q.ndim >= 2 else q.reshape(-1, 1)
        out[path] = {
            "q": packing.pack_matrix(q2d, spec.bits),
            "delta": jnp.asarray(delta, jnp.float32),
            "bits": jnp.asarray(spec.bits, jnp.int32),
            "shape": jnp.asarray(leaf.shape, jnp.int32),
        }
    return unflatten(out)


def packed_apply(packed: Dict[str, Any], x: jnp.ndarray, *,
                 use_kernel: bool = True) -> jnp.ndarray:
    """Inference matmul against a legacy packed leaf from export_packed."""
    from repro.core import packing

    shape = tuple(int(s) for s in packed["shape"])
    bits = int(packed["bits"])
    k = 1
    for s in shape[:-1]:
        k *= s
    if use_kernel and x.ndim == 2 and bits == 3:
        from repro.kernels.qmatvec import ops as qmv_ops
        return qmv_ops.qmatvec(x, packed["q"], packed["delta"], k=k)
    q = packing.unpack_matrix(packed["q"], k, bits).reshape(shape)
    w = q.astype(jnp.float32) * packed["delta"]
    return x @ w.astype(x.dtype)
