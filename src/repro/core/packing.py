"""Sub-byte weight packing (the paper's on-chip storage format, §2.2/§5 of DESIGN).

Two formats:

1. ``pack_int32`` / ``unpack_int32`` — container format. ``fields`` b-bit
   two's-complement fields per int32 word (10 fields for b=3: 30 bits used,
   matching the paper's 3-bit BRAM words; 16 for b=2; 8 for b=4; 4 for b=8).
   This is the checkpoint/serving storage format and the HBM streaming format
   of the decode ``qmatvec`` kernel — 3.2 bits of HBM traffic per 3-bit weight.

2. int8 "plane" format — the level value stored directly in int8. Used by the
   compute-bound ``qmatmul`` kernel where MXU operand alignment matters more
   than the last 2.5x of weight bandwidth (see DESIGN §5).

All functions are pure jnp and jit-safe; shapes are static.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "fields_per_word",
    "packed_words",
    "pack_int32",
    "unpack_int32",
    "pack_matrix",
    "unpack_matrix",
    "packed_nbytes",
]


def fields_per_word(bits: int) -> int:
    """How many b-bit fields fit one int32 word (30 bits used for b=3)."""
    if bits not in (2, 3, 4, 8):
        raise ValueError(f"unsupported pack width: {bits}")
    return {2: 16, 3: 10, 4: 8, 8: 4}[bits]


def packed_words(n: int, bits: int) -> int:
    f = fields_per_word(bits)
    return (n + f - 1) // f


def _check_levels(q: jnp.ndarray, bits: int) -> None:
    """Enforce the pack contract on concrete inputs: every level must lie in
    the b-bit two's-complement range [-(2^(b-1)), 2^(b-1)-1]. Out-of-range
    values would be silently truncated to their low b bits (a wrong but
    plausible-looking weight) — reject them instead. Traced values cannot be
    inspected; under jit the contract is the caller's responsibility."""
    import numpy as np

    try:
        qn = np.asarray(q)
    except jax.errors.TracerArrayConversionError:
        return
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    if qn.size and (qn.min() < lo or qn.max() > hi):
        raise ValueError(
            f"levels out of range for {bits}-bit packing: got "
            f"[{qn.min()}, {qn.max()}], contract is [{lo}, {hi}]")


@partial(jax.jit, static_argnames=("bits",))
def _pack_int32_impl(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    f = fields_per_word(bits)
    mask = (1 << bits) - 1
    n = q.shape[0]
    nw = packed_words(n, bits)
    qp = jnp.zeros((nw * f,), jnp.int32).at[:n].set(q.astype(jnp.int32))
    qp = qp.reshape(nw, f) & mask  # two's complement truncation to b bits
    shifts = jnp.arange(f, dtype=jnp.int32) * bits
    return jnp.sum(qp << shifts[None, :], axis=1).astype(jnp.int32)


def pack_int32(q: jnp.ndarray, bits: int = 3) -> jnp.ndarray:
    """Pack a flat int array of b-bit signed levels into int32 words.

    Contract: values MUST lie in [-(2^(b-1)), 2^(b-1)-1] (the quantizer only
    emits [-(2^(b-1)-1), 2^(b-1)-1], so quantized weights always satisfy
    it). Concrete out-of-range inputs raise ``ValueError``; under jit the
    caller must uphold the contract (tracers cannot be inspected).
    """
    _check_levels(q, bits)
    return _pack_int32_impl(q, bits)


@partial(jax.jit, static_argnames=("bits", "n"))
def unpack_int32(words: jnp.ndarray, n: int, bits: int = 3) -> jnp.ndarray:
    """Inverse of :func:`pack_int32`; returns int8 levels of length ``n``."""
    f = fields_per_word(bits)
    mask = (1 << bits) - 1
    sign = 1 << (bits - 1)
    shifts = jnp.arange(f, dtype=jnp.int32) * bits
    fieldsv = (words[:, None] >> shifts[None, :]) & mask
    fieldsv = fieldsv - ((fieldsv & sign) << 1)  # sign extend
    return fieldsv.reshape(-1)[:n].astype(jnp.int8)


def pack_matrix(q: jnp.ndarray, bits: int = 3) -> jnp.ndarray:
    """Pack a (K, N) int level matrix along K into (ceil(K/f), N) int32.

    Packing along K (the reduction axis) keeps each output column's weights
    contiguous per word, which is what the decode matvec kernel streams.
    Same range contract as :func:`pack_int32`: concrete levels outside the
    b-bit two's-complement range raise ``ValueError``.
    """
    _check_levels(q, bits)
    return jax.vmap(lambda col: _pack_int32_impl(col, bits),
                    in_axes=1, out_axes=1)(q)


def unpack_matrix(words: jnp.ndarray, k: int, bits: int = 3) -> jnp.ndarray:
    """Inverse of :func:`pack_matrix` -> (K, N) int8."""
    return jax.vmap(lambda col: unpack_int32(col, k, bits), in_axes=1, out_axes=1)(words)


def packed_nbytes(shape, bits: int) -> int:
    """HBM bytes for a packed tensor of logical ``shape``."""
    import math

    n = math.prod(shape)
    return packed_words(n, bits) * 4
