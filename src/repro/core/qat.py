"""Quantization-aware retraining (the paper's §2.1, step 3).

The paper retrains with fixed-point weights in the forward path while the
backward pass updates a float master copy — the straight-through estimator
(STE). ``fake_quant`` realizes exactly that:

    forward:   w_q = delta * clip(round(w / delta), -M, M)
    backward:  dL/dw = dL/dw_q          (identity through the rounding)

Two delta modes:
  * ``delta=None``  — re-fit the L2-optimal delta *inside* the forward pass
    each step (delta is stop-gradiented; this follows retraining practice of
    Hwang & Sung 2014 where the step size tracks the drifting weights).
  * fixed ``delta`` — frozen from the post-float-training quantization step.

Activations: the paper uses 8-bit signals between layers. ``fake_quant_act``
quantizes activations with a dynamic absmax scale (per leading batch row, so
serving slots stay independent) and STE.

``three_step_pipeline`` drives the full paper recipe:
  1. float training          (caller's train_fn)
  2. optimal uniform quant   (quantizer.quantize on every policy-selected leaf)
  3. retraining with STE     (caller's train_fn with quantized forward enabled)
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantizer as qz

__all__ = ["fake_quant", "fake_quant_act", "ste_round", "ThreeStepResult", "three_step_pipeline"]


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """round(x) with identity gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def fake_quant(w: jnp.ndarray, spec: qz.QuantSpec,
               delta: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """STE fake-quantized view of ``w`` (same dtype/shape as ``w``)."""
    if delta is None:
        delta = jax.lax.stop_gradient(qz.optimal_uniform_delta(w, spec))
    d = qz._broadcast_delta(delta, w.shape, spec.per_channel)
    d = jnp.maximum(d, 1e-12)
    m = float(spec.levels)
    q = jnp.clip(ste_round(w.astype(jnp.float32) / d), -m, m)
    return (q * d).astype(w.dtype)


def fake_quant_act(x: jnp.ndarray, bits: int = 8, signed: bool = True) -> jnp.ndarray:
    """8-bit (default) activation fake-quant, dynamic PER-ROW absmax scale.

    For ``x`` with a leading batch dim (ndim >= 2) the scale is computed per
    leading row — one scale per batch element, reduced over every other
    axis. A per-tensor scale would couple batch rows: in the slot-major
    serving engine one slot's activations would then perturb every other
    slot's quantization grid, breaking batched-vs-solo token parity. Per-row
    scales keep slots independent (and are strictly finer-grained, so QAT
    accuracy only improves). 1-D inputs keep the per-tensor scale.

    For unsigned activations (post-sigmoid, in [0, 1]) use ``signed=False``:
    levels 0..2^b-1, matching the paper's 8-bit inter-tile signals.
    """
    xf = x.astype(jnp.float32)
    axes = tuple(range(1, xf.ndim)) if xf.ndim >= 2 else None
    if signed:
        m = float(2 ** (bits - 1) - 1)
        scale = jax.lax.stop_gradient(
            jnp.max(jnp.abs(xf), axis=axes, keepdims=xf.ndim >= 2))
        scale = jnp.maximum(scale / m, 1e-12)
        q = jnp.clip(ste_round(xf / scale), -m, m)
    else:
        m = float(2 ** bits - 1)
        scale = jax.lax.stop_gradient(
            jnp.max(xf, axis=axes, keepdims=xf.ndim >= 2))
        scale = jnp.maximum(scale / m, 1e-12)
        q = jnp.clip(ste_round(xf / scale), 0.0, m)
    return (q * scale).astype(x.dtype)


class ThreeStepResult(NamedTuple):
    float_params: dict
    quant_params: dict          # float master copy after retraining
    deltas: dict                # per-leaf deltas frozen after step 2
    float_metrics: dict
    retrain_metrics: dict


def three_step_pipeline(
    init_params: dict,
    float_train_fn: Callable[[dict], tuple],
    quantize_tree_fn: Callable[[dict], dict],
    retrain_fn: Callable[[dict, dict], tuple],
) -> ThreeStepResult:
    """Drive the paper's float-train -> quantize -> retrain recipe.

    The three callables own model/optimizer specifics; this driver pins the
    *order* and hands artifacts between the steps:

      float_train_fn(params)            -> (params, metrics)
      quantize_tree_fn(params)          -> deltas pytree (step-2 L2-optimal fit)
      retrain_fn(params, deltas)        -> (params, metrics)   # STE forward
    """
    fparams, fmetrics = float_train_fn(init_params)
    deltas = quantize_tree_fn(fparams)
    qparams, qmetrics = retrain_fn(fparams, deltas)
    return ThreeStepResult(fparams, qparams, deltas, fmetrics, qmetrics)
