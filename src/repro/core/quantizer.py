"""Optimal uniform weight quantization (the paper's §2.1, step 2).

Implements the training-based fixed-point optimization of Park & Sung 2016
(following Hwang & Sung 2014 [14]): given float weights ``w`` and a symmetric
integer level set ``{-M, ..., +M}`` (M = 2^(bits-1) - 1; for the paper's 3-bit
case M = 3, i.e. levels -3..+3 — the -4 code is unused), find the step size
``delta`` minimizing  ``|| w - delta * q ||_2^2``  with
``q = clip(round(w / delta), -M, M)``.

The minimization alternates two exact coordinate-descent steps:

  1. assignment:  q      <- clip(round(w / delta), -M, M)
  2. step fit:    delta  <- <w, q> / <q, q>          (1-D least squares)

Both steps monotonically decrease the L2 error, so the iteration converges
(typically < 20 iterations). This is Lloyd-Max restricted to a uniform grid.

Per-channel quantization applies the same procedure independently per output
channel (``axis``), matching modern practice; the paper used per-layer
(per-tensor) scales — both are supported and the paper's repro configs use
per-tensor.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "QuantSpec",
    "max_level",
    "optimal_uniform_delta",
    "quantize_levels",
    "dequantize",
    "quantize",
    "quantization_mse",
]


def max_level(bits: int) -> int:
    """Largest integer level for a symmetric ``bits``-bit quantizer.

    3 bits -> 3 (levels -3..3, the paper's set); 8 bits -> 127; 2 bits -> 1
    (ternary, Hwang & Sung 2014).
    """
    if bits < 2:
        raise ValueError(f"need >= 2 bits for a symmetric signed quantizer, got {bits}")
    return 2 ** (bits - 1) - 1


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How one tensor is quantized.

    Attributes:
      bits:        total bit width (2..8). ``None``/0 disables quantization.
      per_channel: if not None, the axis treated as output channels; each
                   channel gets its own delta. None = per-tensor (paper).
      iters:       alternating-minimization iterations.
    """

    bits: int = 3
    per_channel: Optional[int] = None
    iters: int = 25

    @property
    def levels(self) -> int:
        return max_level(self.bits)


def _delta_init(w: jnp.ndarray, m: int) -> jnp.ndarray:
    """Initial step size: cover ~full range; robust to all-zero tensors."""
    amax = jnp.max(jnp.abs(w))
    return jnp.where(amax > 0, amax / m, jnp.ones_like(amax))


def _fit_delta(w: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """L2-optimal delta for a fixed assignment: <w,q>/<q,q>."""
    num = jnp.sum(w * q)
    den = jnp.sum(q * q)
    return jnp.where(den > 0, num / jnp.maximum(den, 1e-12), jnp.zeros_like(num))


@partial(jax.jit, static_argnames=("m", "iters"))
def _optimal_delta_flat(w: jnp.ndarray, m: int, iters: int) -> jnp.ndarray:
    """Alternating minimization on a flat (1-D) weight vector. Returns delta."""
    w = w.astype(jnp.float32)

    def body(_, delta):
        q = jnp.clip(jnp.round(w / jnp.maximum(delta, 1e-12)), -m, m)
        new = _fit_delta(w, q)
        # Guard against degenerate all-zero assignment collapsing delta to 0.
        return jnp.where(new > 0, new, delta)

    return jax.lax.fori_loop(0, iters, body, _delta_init(w, m))


def optimal_uniform_delta(w: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """L2-optimal uniform step size(s) for ``w`` under ``spec``.

    Returns a scalar (per-tensor) or a vector of shape ``(w.shape[axis],)``
    (per-channel).
    """
    m = spec.levels
    if spec.per_channel is None:
        return _optimal_delta_flat(w.reshape(-1), m, spec.iters)
    axis = spec.per_channel % w.ndim
    wc = jnp.moveaxis(w, axis, 0).reshape(w.shape[axis], -1)
    return jax.vmap(lambda row: _optimal_delta_flat(row, m, spec.iters))(wc)


def _broadcast_delta(delta: jnp.ndarray, w_shape, axis: Optional[int]) -> jnp.ndarray:
    if axis is None:
        return delta
    axis = axis % len(w_shape)
    shape = [1] * len(w_shape)
    shape[axis] = w_shape[axis]
    return delta.reshape(shape)


def quantize_levels(w: jnp.ndarray, delta: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Integer levels q = clip(round(w/delta), -M, M), int8 dtype."""
    d = _broadcast_delta(delta, w.shape, spec.per_channel)
    q = jnp.clip(jnp.round(w / jnp.maximum(d, 1e-12)), -spec.levels, spec.levels)
    return q.astype(jnp.int8)


def dequantize(q: jnp.ndarray, delta: jnp.ndarray, spec: QuantSpec,
               dtype=jnp.float32) -> jnp.ndarray:
    d = _broadcast_delta(delta, q.shape, spec.per_channel)
    return (q.astype(jnp.float32) * d).astype(dtype)


def quantize(w: jnp.ndarray, spec: QuantSpec):
    """Full pipeline: fit delta, assign levels. Returns (q_int8, delta)."""
    delta = optimal_uniform_delta(w, spec)
    return quantize_levels(w, delta, spec), delta


def quantization_mse(w: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Mean squared quantization error of the L2-optimal quantizer on ``w``."""
    q, delta = quantize(w, spec)
    return jnp.mean((w - dequantize(q, delta, spec, w.dtype)) ** 2)
