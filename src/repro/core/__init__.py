"""repro.core — the paper's contribution: training-based fixed-point
quantization with on-chip-memory-only packed deployment.

Public API:
    QuantSpec, QuantPolicy, W3A8/FLOAT/... policies
    optimal_uniform_delta / quantize / dequantize   (paper step 2)
    fake_quant / fake_quant_act / three_step_pipeline (paper steps 1+3)
    pack_int32 / unpack_int32 / pack_matrix          (on-chip storage format)
    quant_dense.{init, apply, fit_deltas, export_packed}
"""
from repro.core.precision import FLOAT, TERNARY, W3A8, W4A8, W8, QuantPolicy
from repro.core.quantizer import (QuantSpec, dequantize, max_level,
                                  optimal_uniform_delta, quantization_mse,
                                  quantize, quantize_levels)
from repro.core.qat import fake_quant, fake_quant_act, ste_round, three_step_pipeline
from repro.core.packing import (fields_per_word, pack_int32, pack_matrix,
                                packed_nbytes, packed_words, unpack_int32,
                                unpack_matrix)

__all__ = [
    "QuantSpec", "QuantPolicy", "FLOAT", "W3A8", "W4A8", "W8", "TERNARY",
    "optimal_uniform_delta", "quantize", "quantize_levels", "dequantize",
    "quantization_mse", "max_level",
    "fake_quant", "fake_quant_act", "ste_round", "three_step_pipeline",
    "pack_int32", "unpack_int32", "pack_matrix", "unpack_matrix",
    "packed_words", "packed_nbytes", "fields_per_word",
]
