"""Crash durability for the serving engine: snapshots + write-ahead journal.

The paper's deployment premise — the whole quantized model resident in
on-chip memory for the life of the service — makes a process death
expensive: the packed weight image, the slot-major KV/SSM state, and every
in-flight request die with it. This module makes that loss bounded and
recoverable with two cooperating mechanisms:

  * **Snapshots** — :func:`snapshot_engine` captures the COMPLETE engine
    state at a tick boundary: the device trees (shared cache, drafter
    cache, per-slot token/active/emitted/budget vectors, the sampling RNG
    key) via :func:`repro.models.api.cache_to_host`, plus the host
    bookkeeping (queue / resident / finished requests, per-slot tick
    budgets, every counter, the degradation-ladder mode the engine was
    running in). Persistence rides :func:`repro.checkpoint.save` — atomic
    tmp+rename step dirs keyed by ``decode_calls``, keep-k GC — so a crash
    mid-snapshot never leaves a half-written restore point. The engine
    syncs its async pending buffer first, so a snapshot is always at a
    consistent "everything attributed" boundary, and restoring it resumes
    the token stream exactly where it left off (token-identical at T=0:
    decode is deterministic given cache + RNG key, both captured).
  * **Write-ahead journal** — :class:`Journal`, an append-only JSONL log
    of ``submit`` / ``admit`` / ``commit`` / ``finish`` / ``shed`` events
    (flushed per event; a torn final line from a mid-write crash is
    detected and dropped on read). Replay does NOT try to reconstruct
    device state from events — it restores the latest snapshot and then
    RESUBMITS the journal tail's accepted submits (uid and deadline
    preserved). Determinism does the rest: a resubmitted request
    recomputes the exact tokens the dead process would have produced
    (T=0; same weight-only-quant row-independence argument as
    preemption), so recovery is at-least-once delivery with zero accepted
    tokens lost. Requests the dead process had already shed, expired, or
    quarantined stay dead (their terminal outcome was already reported).

:func:`recover` glues the two together: restore the newest snapshot (if
any), find the last ``snapshot`` marker for that step in the journal, and
resubmit the accepted-but-not-terminal submits recorded after it. A
journal with no snapshot replays from the beginning onto a fresh engine.

The at-risk window is what was DRAINED to the caller between the last
snapshot and the crash: those requests are gone from the engine and are
simply recomputed and re-delivered (at-least-once). Nothing accepted is
ever silently lost — the acceptance test in tests/test_durability.py
crashes ``run_all`` at arbitrary ticks and checks the union of pre-crash
drains and post-recovery output against an uncrashed run.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.models import api as model_api

__all__ = ["Journal", "snapshot_engine", "restore_engine", "recover"]

FORMAT = 1

# engine counters captured verbatim in a snapshot and restored verbatim —
# a recovered engine reports the same totals the dead one had accumulated
_COUNTERS = (
    "decode_calls", "prefill_calls", "spec_drafted", "spec_accepted",
    "shed_count", "deadline_miss_count", "preempt_count", "poisoned_count",
    "queue_peak", "snapshots_written", "journal_events", "replayed_events",
    "integrity_probes", "heal_count",
)

# terminal Request.status values that stay dead across recovery: their
# outcome was already reported to the caller, so replay must not resurrect
# them ("ok" finishes ARE recomputed — at-least-once delivery)
_DEAD_STATUS = ("shed", "deadline", "poisoned")


class Journal:
    """Append-only JSONL write-ahead log. One JSON object per line,
    flushed per event, opened in append mode so a recovered engine keeps
    extending the same history. ``fsync=True`` additionally fsyncs every
    append (durable against power loss, not just process death)."""

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a")
        self._fsync = fsync

    def append(self, event: Dict[str, Any]):
        self._f.write(json.dumps(event, separators=(",", ":")) + "\n")
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())

    def close(self):
        if not self._f.closed:
            self._f.close()

    @staticmethod
    def read(path: str) -> List[Dict[str, Any]]:
        """Events in order. A torn final line (crash mid-append) is
        dropped; a torn line ANYWHERE truncates the replay there — events
        after a corruption can't be trusted to be ordered."""
        events: List[Dict[str, Any]] = []
        if not os.path.exists(path):
            return events
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    events.append(json.loads(line))
                except json.JSONDecodeError:
                    break
        return events


# --- Request (de)serialization ------------------------------------------------

def _req_to_state(r) -> Dict[str, Any]:
    return {"uid": r.uid, "prompt": list(r.prompt), "max_new": r.max_new,
            "out": list(r.out), "done": r.done, "ticks": r.ticks,
            "accept_hist": {int(k): int(v) for k, v in r.accept_hist.items()},
            "status": r.status, "deadline_at": r.deadline_at,
            "preemptions": r.preemptions, "submit_time": r.submit_time,
            "finish_time": r.finish_time}


def _req_from_state(d: Dict[str, Any]):
    from repro.serving.engine import Request
    return Request(
        uid=int(d["uid"]), prompt=[int(t) for t in d["prompt"]],
        max_new=int(d["max_new"]), out=[int(t) for t in d["out"]],
        done=bool(d["done"]), ticks=int(d["ticks"]),
        # JSON stringifies int keys; undo that on the way back
        accept_hist={int(k): int(v) for k, v in d["accept_hist"].items()},
        status=str(d["status"]),
        deadline_at=None if d["deadline_at"] is None else int(d["deadline_at"]),
        preemptions=int(d["preemptions"]),
        submit_time=float(d["submit_time"]),
        finish_time=float(d["finish_time"]))


# --- snapshot / restore -------------------------------------------------------

def snapshot_engine(eng, snapshot_dir: str, *, keep: int = 3) -> str:
    """Persist the engine's complete state under ``snapshot_dir`` (one
    atomic ``step_<decode_calls>`` dir; ``keep`` newest retained). Syncs
    the async pending buffer first so every emitted token is attributed —
    the snapshot is a consistent tick boundary. Returns the path and logs
    a ``snapshot`` marker to the journal (the replay cut point)."""
    from repro import checkpoint
    eng._sync()
    dev: Dict[str, Any] = {
        "cache": model_api.cache_to_host(eng.cfg, eng.cache),
        "tokens": eng._tokens, "active": eng._active,
        "emitted": eng._emitted, "budget": eng._budget,
        # the ONLY sampling randomness in the engine: every tick/admission
        # splits from this key host-side, so capturing it makes a restored
        # run reproducible at any temperature
        "rng_key": eng._key,
    }
    if eng._spec:
        dev["draft_cache"] = model_api.cache_to_host(eng.draft_cfg,
                                                     eng.draft_cache)
    state = {
        "format": FORMAT,
        "compat": {
            "cfg": eng.cfg.name, "family": eng.cfg.family,
            "slots": eng.slots, "max_len": eng.max_len,
            "kv_bits": eng.kv_bits, "temperature": eng.temperature,
            "eos_id": eng.eos_id, "dtype": str(np.dtype(eng.dtype)),
        },
        "modes": {"spec": eng._spec, "was_spec": eng._was_spec,
                  "spec_k": eng.spec_k, "matmul_mode": eng.matmul_mode,
                  "attn_mode": eng.attn_mode},
        "queue": [_req_to_state(r) for r in eng.queue],
        "slots": [None if r is None else _req_to_state(r)
                  for r in eng._slot_req],
        "finished": [_req_to_state(r) for r in eng._finished],
        "ticks_left": [int(x) for x in eng._ticks_left],
        "slot_ticks": [int(x) for x in eng._slot_ticks],
        "uid": eng._uid,
        "counters": {k: int(getattr(eng, k)) for k in _COUNTERS},
        "fallback_events": [[int(t), str(lbl)]
                            for t, lbl in eng.fallback_events],
    }
    path = checkpoint.save(snapshot_dir, eng.decode_calls, dev,
                           meta={"serving_state": state}, keep=keep)
    eng.snapshots_written += 1
    eng._last_snapshot_tick = eng.decode_calls
    eng._log_event({"e": "snapshot", "step": eng.decode_calls, "path": path})
    return path


def _check_compat(eng, compat: Dict[str, Any]):
    mine = {"cfg": eng.cfg.name, "family": eng.cfg.family,
            "slots": eng.slots, "max_len": eng.max_len,
            "kv_bits": eng.kv_bits, "temperature": eng.temperature,
            "eos_id": eng.eos_id, "dtype": str(np.dtype(eng.dtype))}
    bad = [f"{k}: snapshot {compat[k]!r} != engine {mine[k]!r}"
           for k in mine if compat.get(k) != mine[k]]
    if bad:
        raise ValueError("snapshot is incompatible with this engine — "
                         + "; ".join(bad))


def _apply_modes(eng, modes: Dict[str, Any]):
    """Put the engine in the mode the snapshot was taken in. A pre-crash
    degradation (spec dropped, kernels swapped for fallback graphs) is
    part of the state: replaying it keeps the restored token stream
    identical to the dead engine's."""
    from repro.serving import engine as engine_mod
    if modes["spec"] and not eng._spec:
        raise ValueError(
            "snapshot was taken in speculative mode but this engine was "
            "built with spec_k=0 — construct it with the original spec_k")
    if modes["spec"] and modes["spec_k"] != eng.spec_k:
        raise ValueError(f"snapshot spec_k {modes['spec_k']} != engine "
                         f"spec_k {eng.spec_k}")
    if not modes["spec"] and eng._spec:
        eng._disable_spec()                  # the dead engine had degraded
    eng._was_spec = bool(modes["was_spec"])
    if (modes["matmul_mode"] != eng.matmul_mode
            or modes["attn_mode"] != eng.attn_mode):
        eng.matmul_mode = modes["matmul_mode"]
        eng.attn_mode = modes["attn_mode"]
        eng._attn_kw = engine_mod._attn_kwargs(eng.cfg, eng.attn_mode,
                                               eng.kv_bits)
        if eng._spec:
            eng._dattn_kw = engine_mod._attn_kwargs(eng.draft_cfg,
                                                    eng.attn_mode,
                                                    eng.kv_bits)
        eng._build_jits()


def restore_engine(eng, snapshot_dir: str,
                   step: Optional[int] = None) -> Dict[str, Any]:
    """Load a snapshot into ``eng`` (a freshly constructed engine with the
    same params/config). Validates compatibility loudly, replays the
    snapshot's degradation mode, and swaps in the device trees via
    :func:`repro.models.api.cache_from_host` (structure/shape/dtype
    checked against the live cache). Returns the snapshot's host state."""
    from repro import checkpoint
    dev, meta = checkpoint.restore(snapshot_dir, step)
    state = meta["serving_state"]
    if state.get("format") != FORMAT:
        raise ValueError(f"unknown snapshot format {state.get('format')!r}")
    _check_compat(eng, state["compat"])
    _apply_modes(eng, state["modes"])
    eng.cache = model_api.cache_from_host(eng.cfg, dev["cache"],
                                          like=eng.cache)
    if eng._spec:
        if "draft_cache" not in dev:
            raise ValueError("speculative engine but snapshot carries no "
                             "draft cache")
        eng.draft_cache = model_api.cache_from_host(
            eng.draft_cfg, dev["draft_cache"], like=eng.draft_cache)
    eng._tokens = jnp.asarray(np.asarray(dev["tokens"], np.int32))
    eng._active = jnp.asarray(np.asarray(dev["active"], bool))
    eng._emitted = jnp.asarray(np.asarray(dev["emitted"], np.int32))
    eng._budget = jnp.asarray(np.asarray(dev["budget"], np.int32))
    eng._key = jnp.asarray(np.asarray(dev["rng_key"], np.uint32))
    eng.queue = [_req_from_state(d) for d in state["queue"]]
    eng._slot_req = [None if d is None else _req_from_state(d)
                     for d in state["slots"]]
    eng._finished = [_req_from_state(d) for d in state["finished"]]
    eng._ticks_left = [int(x) for x in state["ticks_left"]]
    eng._slot_ticks = [int(x) for x in state["slot_ticks"]]
    eng._pending = []
    eng._uid = int(state["uid"])
    for k in _COUNTERS:
        setattr(eng, k, int(state["counters"][k]))
    eng.fallback_events = [(int(t), str(lbl))
                           for t, lbl in state["fallback_events"]]
    # a restored engine must not immediately re-snapshot the same tick
    eng._last_snapshot_tick = eng.decode_calls
    return state


# --- journal replay -----------------------------------------------------------

def recover(eng, *, snapshot_dir: Optional[str] = None,
            journal: Optional[str] = None) -> Dict[str, Any]:
    """Full recovery onto a freshly constructed engine: restore the newest
    snapshot under ``snapshot_dir`` (if any), then replay the journal tail
    — every accepted submit recorded after that snapshot's marker whose
    request is neither already baked into the snapshot nor terminally dead
    (shed/deadline/poisoned) is resubmitted with its original uid and
    deadline. Returns ``{"restored_step", "replayed_events",
    "resubmitted"}``. ``run_all()`` afterwards completes every recovered
    request; at T=0 the recomputed tokens are identical to what the dead
    engine would have produced."""
    import time as _time
    from repro import checkpoint
    from repro.serving.engine import Request
    stats = {"restored_step": None, "replayed_events": 0, "resubmitted": 0}
    step = None
    if snapshot_dir is not None:
        step = checkpoint.latest_step(snapshot_dir)
        if step is not None:
            restore_engine(eng, snapshot_dir, step)
            stats["restored_step"] = step
    if journal is None:
        return stats
    events = Journal.read(journal)
    start = 0
    if step is not None:
        for i, ev in enumerate(events):
            if ev.get("e") == "snapshot" and ev.get("step") == step:
                start = i + 1                # LAST marker for that step wins
    tail = events[start:]
    stats["replayed_events"] = len(tail)
    known = ({r.uid for r in eng.queue}
             | {r.uid for r in eng._slot_req if r is not None}
             | {r.uid for r in eng._finished})
    submits: Dict[int, Dict[str, Any]] = {}
    dead: set = set()
    order: List[int] = []
    for ev in tail:
        kind = ev.get("e")
        uid = ev.get("uid")
        if kind == "submit" and uid is not None:
            submits[uid] = ev
            order.append(uid)
        elif kind == "shed" and uid is not None:
            dead.add(uid)
        elif kind == "finish" and ev.get("status") in _DEAD_STATUS:
            dead.add(uid)
    for uid in order:
        if uid in dead or uid in known:
            continue
        ev = submits[uid]
        req = Request(uid=int(uid), prompt=[int(t) for t in ev["prompt"]],
                      max_new=int(ev["max_new"]),
                      deadline_at=(None if ev.get("deadline_at") is None
                                   else int(ev["deadline_at"])),
                      submit_time=_time.perf_counter())
        eng.queue.append(req)
        stats["resubmitted"] += 1
    if submits:
        eng._uid = max(eng._uid, max(submits))
    eng.queue_peak = max(eng.queue_peak, len(eng.queue))
    eng.replayed_events += stats["replayed_events"]
    return stats
