"""Overload-hardening primitives for the serving stack.

The paper's premise is serving a fixed workload from a *hard* resource
budget (everything lives in on-chip memory; there is no DRAM to spill
into), and the roadmap's north star makes overload the normal operating
regime, not an exception. This module holds the host-side vocabulary the
:class:`~repro.serving.engine.ServingEngine` uses to stay live under that
regime — nothing here touches a device array:

  * **Bounded admission** — :class:`SubmitOutcome` (the structured
    accept/shed result of ``submit()``; an ``int`` subclass so existing
    ``uid = eng.submit(...)`` callers keep working) and
    :class:`SubmitRejected` (a ``ValueError`` subclass carrying a
    machine-readable ``reason`` code shared with the shed path).
  * **Deadlines / preemption / quarantine outcomes** — the
    :data:`STATUS` vocabulary a drained ``Request`` reports
    (``ok``/``deadline``/``shed``/``poisoned``).
  * **Degradation ladder** — :func:`degrade_step` applies the next
    fallback when a jitted tick call fails: a speculative engine drops to
    the plain tick (drafter abandoned, target stream unaffected), a
    kernel-mode engine drops to the dequant/ref graphs. Each step rebuilds
    the engine's jits; if no step is left the original failure propagates.
  * **Watchdog** — :class:`WatchdogExpired`, raised by
    ``run_all(max_ticks=)`` with a diagnostic dump (queue depth, active
    slots, per-slot tick budgets) instead of spinning forever.
  * **Deterministic fault injection** — :class:`FaultPlan` describes NaN
    logits (per tick x slot), one-shot jitted-tick failures, and admission
    delays; the engine's test-only ``fault_plan=`` hook threads it through
    every recovery path above so resilience is *exercised* by tests and
    the CI chaos-smoke run, not just claimed. NaN injection rides the
    ``poison`` bias vector that is ALWAYS an input of the jitted tick
    (zeros in healthy operation), so injecting never retraces and the
    on-device health check it exercises costs no extra sync — the
    per-slot non-finite flag is one more array in the ``_pending`` drain.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Optional, Tuple

__all__ = ["SHED_POLICIES", "STATUS", "SubmitOutcome", "SubmitRejected",
           "InjectedFault", "InjectedCrash", "WatchdogExpired", "FaultPlan",
           "degrade_step"]

SHED_POLICIES = ("reject", "drop_oldest")

# terminal Request.status values a drained request can carry
STATUS = ("ok",          # finished normally (budget or EOS)
          "deadline",    # cancelled mid-stream/in-queue past its deadline
          "shed",        # dropped by bounded admission (drop_oldest)
          "poisoned")    # quarantined: non-finite logits in its slot


class SubmitRejected(ValueError):
    """``submit()`` refused a request. ``reason`` is a machine-readable
    code (``empty_prompt`` / ``bad_max_new`` / ``too_long`` /
    ``bad_deadline``) shared with the shed path's outcome reasons;
    ``ValueError`` stays the base class so pre-existing callers that catch
    or ``pytest.raises`` ValueError keep working."""

    def __init__(self, reason: str, message: str):
        super().__init__(message)
        self.reason = reason


class SubmitOutcome(int):
    """Structured result of ``submit()``.

    An ``int`` subclass whose value is the accepted request's uid (uids
    start at 1), or 0 when the request was shed — so truthiness means
    "admitted", and legacy callers that use the return value as the uid
    (``uid_to_prompt[eng.submit(p)] = p``) are unchanged. ``reason`` is
    None on acceptance or the shed reason code (``queue_full``);
    ``shed`` lists uids of QUEUED requests evicted to make room
    (``drop_oldest`` policy)."""

    accepted: bool
    reason: Optional[str]
    shed: Tuple[int, ...]

    def __new__(cls, uid: int, *, accepted: bool,
                reason: Optional[str] = None,
                shed: Tuple[int, ...] = ()):
        self = super().__new__(cls, uid)
        self.accepted = accepted
        self.reason = reason
        self.shed = tuple(shed)
        return self

    @property
    def uid(self) -> Optional[int]:
        return int(self) if self.accepted else None

    def __repr__(self):
        if self.accepted:
            extra = f", shed={self.shed}" if self.shed else ""
            return f"SubmitOutcome(uid={int(self)}{extra})"
        return f"SubmitOutcome(rejected, reason={self.reason!r})"


class InjectedFault(RuntimeError):
    """The failure :class:`FaultPlan` raises in place of a jitted tick
    call — a distinct type so tests can tell injected faults from real
    ones, while the engine's recovery path treats both identically."""


class InjectedCrash(RuntimeError):
    """The simulated process kill :class:`FaultPlan.crash_at_tick` raises
    from ``step()`` — deliberately NOT recoverable by the degradation
    ladder (a dead process cannot retry anything). Recovery is the
    durability path: a NEW engine restored from the latest on-disk
    snapshot plus the write-ahead journal tail
    (:func:`repro.serving.durability.recover`)."""


class WatchdogExpired(RuntimeError):
    """``run_all(max_ticks=)`` exceeded its tick budget with work still
    queued or resident — the engine is wedged (or the budget is simply too
    small for the workload). Carries ``diagnostics``: queue depth, active
    slot count, per-slot ``{slot: (uid, ticks_left)}``, and the engine
    counters, so the dump names what is stuck instead of spinning."""

    def __init__(self, message: str, diagnostics: Dict):
        super().__init__(message)
        self.diagnostics = diagnostics


def _as_tick_slot_pairs(pairs) -> FrozenSet[Tuple[int, int]]:
    return frozenset((int(t), int(s)) for t, s in pairs)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of injected faults, keyed on the engine's
    ``decode_calls`` tick counter (admission delays are checked at the
    spin-up preceding the tick with that index).

    ``nan_logits``   {(tick, slot), ...}: add NaN to that slot's logits
                     inside the jitted tick — exercises the on-device
                     health check and the quarantine path.
    ``fail_ticks``   {tick, ...}: raise :class:`InjectedFault` IN PLACE of
                     the jitted tick call, once per listed tick —
                     exercises the degradation ladder. (The fault fires
                     before the call, so donated buffers are intact and
                     the retried tick sees consistent state.)
    ``delay_admission`` {tick, ...}: skip the admission round at that
                     tick — exercises queue aging under deferred
                     admission (deadlines can expire while queued).
    ``crash_at_tick`` Optional[int]: raise :class:`InjectedCrash` from
                     ``step()`` at that tick — a simulated process kill
                     that BYPASSES the degradation ladder (nothing in the
                     dying process recovers; the durability layer's
                     snapshot + journal must). Everything on device and in
                     host bookkeeping at that instant is lost, exactly as
                     a real SIGKILL would lose it.
    ``flip_bits``    {(tick, path, bit), ...}: flip one BIT of the params
                     leaf at tree path ``path`` (e.g.
                     ``"layers/mlp/w_in/qp"``) at the start of that tick —
                     a soft error in the resident packed weight store,
                     the fault class the paper's weights-live-on-chip
                     thesis makes permanent (no DRAM reload ever rights
                     it). Exercises the integrity probe + self-heal path.

    Instances are immutable; one-shot consumption state (``fail_ticks``
    firing once each) lives in the engine, not here, so a plan can be
    shared across engines and reruns deterministically.
    """

    nan_logits: FrozenSet[Tuple[int, int]] = frozenset()
    fail_ticks: FrozenSet[int] = frozenset()
    delay_admission: FrozenSet[int] = frozenset()
    crash_at_tick: Optional[int] = None
    flip_bits: FrozenSet[Tuple[int, str, int]] = frozenset()

    def __init__(self, nan_logits=(), fail_ticks=(), delay_admission=(),
                 crash_at_tick=None, flip_bits=()):
        object.__setattr__(self, "nan_logits",
                           _as_tick_slot_pairs(nan_logits))
        object.__setattr__(self, "fail_ticks",
                           frozenset(int(t) for t in fail_ticks))
        object.__setattr__(self, "delay_admission",
                           frozenset(int(t) for t in delay_admission))
        object.__setattr__(self, "crash_at_tick",
                           None if crash_at_tick is None
                           else int(crash_at_tick))
        object.__setattr__(self, "flip_bits",
                           frozenset((int(t), str(p), int(b))
                                     for t, p, b in flip_bits))

    # --- queries the engine makes, all O(1)-ish on host ints -----------------

    def nan_slots_at(self, tick: int) -> Tuple[int, ...]:
        return tuple(sorted(s for t, s in self.nan_logits if t == tick))

    def fails_at(self, tick: int) -> bool:
        return tick in self.fail_ticks

    def delays_admission_at(self, tick: int) -> bool:
        return tick in self.delay_admission

    def crashes_at(self, tick: int) -> bool:
        return self.crash_at_tick is not None and tick == self.crash_at_tick

    def flips_at(self, tick: int) -> Tuple[Tuple[str, int], ...]:
        return tuple(sorted((p, b) for t, p, b in self.flip_bits
                            if t == tick))

    @property
    def empty(self) -> bool:
        return not (self.nan_logits or self.fail_ticks
                    or self.delay_admission or self.flip_bits
                    or self.crash_at_tick is not None)

    @classmethod
    def random(cls, seed: int, *, ticks: int, slots: int,
               nan_rate: float = 0.05, fail_rate: float = 0.05,
               delay_rate: float = 0.1) -> "FaultPlan":
        """A seeded chaos schedule over ``ticks`` x ``slots`` — the CI
        chaos-smoke generator. Same seed, same plan."""
        import random as _random
        rng = _random.Random(seed)
        nan, fail, delay = [], [], []
        for t in range(ticks):
            if rng.random() < nan_rate:
                nan.append((t, rng.randrange(slots)))
            if rng.random() < fail_rate:
                fail.append(t)
            if rng.random() < delay_rate:
                delay.append(t)
        return cls(nan_logits=nan, fail_ticks=fail, delay_admission=delay)


def degrade_step(engine) -> Optional[str]:
    """Apply the next degradation-ladder step to ``engine`` after a tick
    failure. Returns a label describing the step taken, or None when the
    ladder is exhausted (the caller re-raises the original failure).

    Ladder (each step rebuilds the engine's jitted graphs; engine state —
    caches, per-slot masks, host bookkeeping — is untouched, which is
    sound because injected/trace-time failures raise before any donated
    buffer is consumed):

      1. speculative tick -> plain tick: the drafter and its cache are
         abandoned; the target stream is unaffected (spec is exact, so
         dropping it changes throughput, never tokens).
      2. kernel graphs -> fallback graphs: ``matmul_mode='dequant'``,
         ``attn_mode='ref'`` — the parity-oracle paths every kernel is
         tested against.
    """
    if engine._spec:
        engine._disable_spec()
        return "spec->plain"
    if engine.matmul_mode != "dequant" or engine.attn_mode != "ref":
        engine._fallback_modes()
        return "kernel->fallback"
    return None
