"""Truly batched continuous-batching serving engine.

The paper's throughput argument (Fig. 4 dataflow) is that quantized weights
are streamed once per step *regardless of batch size*, so batching is what
amortizes the 3-bit weight traffic. This engine realizes that on the serving
side:

  * ONE shared slot-major cache — ``(slots, ...)`` batch layout with per-slot
    length counters — allocated once at construction (all three families:
    KV cache, SSM state, hybrid group state; all three weight forms: ``w``
    float, ``q`` levels, ``qp`` packed containers).
  * Admission is LENGTH-BUCKETED and batched: queued prompts are padded to a
    small set of power-of-two length buckets and every same-bucket request
    is prefilled in ONE jitted call (``prefill(..., lengths=)`` — families
    are padding-exact) and inserted with ONE jitted multi-slot scatter
    (``insert_prefill_many``). The prefill batch dimension is pinned to
    ``slots`` (short admissions are padded with dummy rows whose slot-map
    entry is out of range, so the scatter drops them), which bounds jit
    re-traces to O(#buckets) — not O(#distinct prompt lengths) — and keeps
    the 3-bit weight stream amortized across requests during admission,
    exactly as the decode tick amortizes it across slots. ``prefill_calls``
    counts batched prefill invocations the way ``decode_calls`` counts
    ticks.
  * ONE jitted ``decode_step`` per tick advances every active slot at once.
    Sampling and termination (budget exhausted / EOS) are computed on-device
    as masks; inactive slots are frozen in-graph (token and length held), so
    a tick never needs to know on the host which slots are live.
  * Results are drained asynchronously: each tick appends small device
    arrays to a pending buffer; tokens only cross to the host in bulk at
    ``drain()`` — there is no per-token host sync.

When ``eos_id`` is None request lifetimes are host-predictable (exactly
``max_new`` tokens), so admission needs no sync at all. ``run_all`` drains
every ``drain_every`` ticks — the async window: larger values sync less
often but hold more pending per-tick records; with EOS enabled the periodic
drain is also what discovers early-freed slots.

Quantized matmuls follow ``matmul_mode``: 'kernel' routes every serve-form
(``q``/``qp``) weight through the Pallas qmatvec/qmatmul kernels (weights
expanded only in VMEM — interpret mode off-TPU, for tests), 'dequant' uses
the fused levels-matmul fallback, 'auto' (default) picks 'kernel' on TPU.
In no serve mode does the decode graph materialize a dequantized weight
matrix.

Decode attention follows ``attn_mode`` the same way: 'kernel' runs the
fused Pallas ``kernels.attn_decode`` kernel (QK^T -> online softmax -> PV
in VMEM, per-slot valid-length block skipping), 'ref' the einsum path,
'auto' kernel on TPU. ``kv_bits=8`` stores the shared KV cache as int8 +
per-token scales — half the cache bytes per slot, so a fixed cache budget
holds twice the slots — for the transformer family AND hybrid; the decode
paths read the int8 cache directly (scales fused into attention).

Speculative decoding (``spec_k >= 1``) changes the tick from "one token"
to "up to spec_k+1 tokens": a quantized DRAFTER (by default the packed
3-bit ``qp`` export of the target's own weights — ``api.draft_of``) runs
``spec_k`` cheap ``decode_step`` proposals through the very same fused
kernel path, the target scores all of them plus a bonus position in ONE
multi-token ``verify_step``, vectorized acceptance-rejection keeps the
longest target-consistent prefix (exact target distribution at any
temperature; token-identical to non-spec greedy at T=0), and
``rollback_cache`` rewinds both caches past the rejected suffix — all
inside the SAME single jitted tick, so there is still no per-token (or
per-draft-token) host sync. Per-slot acceptance lengths fold into the
existing on-device active/emitted/budget masks; host bookkeeping only
learns token counts at ``drain()``. Families: dense/moe/hybrid (``ssm``
rejects spec mode loudly — SSD state can't rewind), and for sliding-window
archs the engine requires ``max_len <= window`` so speculation never
wraps the KV ring (a wrapped rewind would lose overwritten entries).

Overload hardening (``serving.resilience``): admission is BOUNDED —
``queue_limit`` + ``shed_policy`` turn ``submit()`` into a structured
accept/shed outcome with ``shed_count``/queue-depth counters instead of an
unbounded queue; per-request DEADLINES (``submit(..., deadline_ticks=)`` /
engine ``default_deadline``) cancel expired requests mid-stream on the
host side (slot freed and zeroed, partial output returned with
``Request.status == "deadline"``); slot PREEMPTION (``preempt_after``)
snapshots a long-running slot's committed tokens when the queue has
waiters, frees the slot, and requeues the request through the normal
bucketed prefill path (token-parity-exact at T=0 — greedy continuation
from prompt+committed is the unpreempted continuation); an on-device
HEALTH CHECK folded into every jitted tick (one per-slot isfinite
reduction riding the existing ``_pending`` drain — no extra sync)
quarantines slots whose logits go non-finite (``status == "poisoned"``,
row zeroed, ``poisoned_count``) instead of silently emitting garbage; a
DEGRADATION LADDER retries a failed tick call on progressively simpler
graphs (spec -> plain tick, kernel -> dequant/ref); and ``run_all(
max_ticks=)`` is a WATCHDOG that raises a diagnostic dump instead of
spinning forever. A deterministic ``resilience.FaultPlan`` (test-only
``fault_plan=`` hook) injects NaN logits / tick failures / admission
delays so every recovery path is exercised by tests and CI.

Durability (``serving.durability`` + ``checkpoint.integrity``): periodic
SNAPSHOTS (``snapshot_dir``/``snapshot_every``, or explicit
``snapshot()``) persist the complete engine state — device cache trees,
per-slot vectors, the sampling RNG key, and all host bookkeeping — as
atomic restore points; a WRITE-AHEAD JOURNAL (``journal=``) logs
submit/admit/commit/finish/shed events per tick so ``recover()`` on a
fresh engine restores the latest snapshot and resubmits the journal tail
(uids/deadlines preserved — at T=0 the recomputed stream is
token-identical, so a crash at ANY tick loses no accepted tokens); and a
WEIGHT-INTEGRITY probe (``integrity_every``, optional ``golden_dir``)
runs a cheap in-graph canary fingerprint over the packed
``qp``/``q``/``delta`` containers every N ticks, detecting any single-bit
soft error in the resident store (``FaultPlan.flip_bits`` injects them)
and SELF-HEALING: the corrupt container is reloaded from its golden copy
and every request whose tokens could have touched the corrupt weights is
rewound to its prompt and requeued through normal admission.

Caveat: for the ``moe`` family, expert-capacity dropping couples batch rows
— a slot's tokens can depend on what else is in the batch. Dynamic
activation scales (``policy.act_bits``) are per-ROW (each batch row gets
its own absmax), so decode ticks are row-independent; batched-prefill
parity under act quant additionally requires the prompt to land exactly on
its admission bucket (padding positions inside a row enter that row's
absmax) — and speculative verify processes spec_k+1 positions per row, so
spec parity likewise needs ``act_bits=None``. Preemption parity inherits
the same condition: the requeued request re-enters through batched prefill
at an arbitrary (mid-stream) length, so with act quant its re-admission
absmax differs from the original admission's and the continuation can
drift; with weight-only quantization the preempted continuation is
token-identical. Dense/ssm/hybrid decode AND
batched prefill with weight-only quantization are row-independent and
therefore token-identical to single-request ``generate``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.precision import QuantPolicy
from repro.models import api as model_api
from repro.models import get_model
from repro.serving import resilience
from repro.serving.resilience import (FaultPlan, SubmitOutcome,
                                      SubmitRejected, WatchdogExpired)

__all__ = ["generate", "Request", "ServingEngine", "FaultPlan",
           "SubmitOutcome", "SubmitRejected", "WatchdogExpired"]

# smallest admission bucket: prompts of length 1..8 share one compilation
_MIN_BUCKET = 8


def _sample(key, logits: jnp.ndarray, temperature: float) -> jnp.ndarray:
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def _attn_kwargs(cfg: ModelConfig, attn_mode: str,
                 kv_bits: Optional[int]) -> Dict[str, Dict[str, Any]]:
    """Validated per-call kwargs for the attention serving knobs.

    ``attn_mode`` goes to ``decode_step`` AND ``prefill`` (the blocked
    Pallas prefill kernel covers admission; ``verify_step`` picks it up via
    the decode kwargs), ``kv_bits=8`` turns into
    ``prefill(quantize_cache=True)`` — all only for the attention-bearing
    families; ``ssm`` takes neither (no attention, no KV cache), and
    asking it to quantize one is a config error, not a silent no-op.
    """
    from repro.models.attention import ATTN_MODES, resolve_attn_mode
    if attn_mode not in ATTN_MODES:
        raise ValueError(f"attn_mode must be one of {ATTN_MODES}, "
                         f"got {attn_mode!r}")
    resolve_attn_mode(attn_mode)           # fail fast on bad explicit modes
    if kv_bits not in (None, 8):
        raise ValueError(f"kv_bits must be None or 8, got {kv_bits!r}")
    if cfg.family == "ssm":
        if kv_bits:
            raise ValueError("kv_bits=8 is meaningless for family 'ssm': "
                             "it has no KV cache to quantize")
        return {"prefill": {}, "decode": {}}
    pf: Dict[str, Any] = {"attn_mode": attn_mode}
    if kv_bits == 8:
        pf["quantize_cache"] = True
    return {"prefill": pf, "decode": {"attn_mode": attn_mode}}


def generate(params, prompts: jnp.ndarray, cfg: ModelConfig, *,
             policy: QuantPolicy, deltas=None, max_new_tokens: int = 32,
             temperature: float = 0.0, seed: int = 0,
             dtype=jnp.bfloat16, matmul_mode: str = "auto",
             attn_mode: str = "auto", kv_bits: Optional[int] = None,
             spec_k: int = 0, draft_params=None,
             draft_cfg: Optional[ModelConfig] = None) -> jnp.ndarray:
    """prompts (B, P) int32 -> (B, P + max_new_tokens). jit-compiled decode.

    ``attn_mode`` picks the attention implementation on every serving path
    — prefill admission and speculative verify (blocked online-softmax
    ``kernels.attn_prefill`` vs chunked/einsum ref) as well as per-token
    decode (fused ``kernels.attn_decode`` vs einsum ref); 'auto' takes the
    kernels on TPU. ``kv_bits=8`` serves from an int8 KV cache. Both knobs
    apply only to the attention-bearing families (``ssm`` ignores
    ``attn_mode`` and rejects ``kv_bits``).

    ``spec_k >= 1`` enables speculative decoding: ``draft_params`` (default:
    the packed-3-bit ``api.draft_of`` export of ``params``) proposes spec_k
    tokens per step and the target verifies them in one multi-token pass —
    same output distribution, token-identical at T=0, fewer target passes.
    The whole decode is one jitted ``lax.while_loop`` (no per-token sync).
    ``ssm`` rejects spec mode (SSD state can't rewind)."""
    if spec_k:
        return _spec_generate(params, prompts, cfg, policy=policy,
                              deltas=deltas, max_new_tokens=max_new_tokens,
                              temperature=temperature, seed=seed, dtype=dtype,
                              matmul_mode=matmul_mode, attn_mode=attn_mode,
                              kv_bits=kv_bits, spec_k=spec_k,
                              draft_params=draft_params, draft_cfg=draft_cfg)
    mod = get_model(cfg)
    b, p = prompts.shape
    max_len = p + max_new_tokens
    attn_kw = _attn_kwargs(cfg, attn_mode, kv_bits)
    logits, cache = mod.prefill(params, {"tokens": prompts}, cfg,
                                policy=policy, deltas=deltas, dtype=dtype,
                                max_len=max_len, matmul_mode=matmul_mode,
                                **attn_kw["prefill"])
    # independent streams: k0 samples the prefill token, the rest drive the
    # scan (sampling with `key` AND scanning over split(key, n) would reuse
    # the same randomness for tok0 and step 0)
    k0, key = jax.random.split(jax.random.PRNGKey(seed))
    tok0 = _sample(k0, logits[:, 0], temperature)[:, None].astype(jnp.int32)
    if max_new_tokens == 1:
        return jnp.concatenate([prompts, tok0], axis=1)

    @jax.jit
    def step(carry, k):
        cache, tok = carry
        logits, cache = mod.decode_step(params, cache, tok, cfg, policy=policy,
                                        deltas=deltas, dtype=dtype,
                                        matmul_mode=matmul_mode,
                                        **attn_kw["decode"])
        nxt = _sample(k, logits[:, 0], temperature)[:, None].astype(jnp.int32)
        return (cache, nxt), nxt

    (cache, _), toks = jax.lax.scan(step, (cache, tok0),
                                    jax.random.split(key, max_new_tokens - 1))
    out = jnp.concatenate([prompts, tok0, toks[:, :, 0].T], axis=1)
    return out


def _no_ring_wrap(mod, cfg: ModelConfig, max_len: int):
    """Speculative rollback is a length rewind: a sliding-window ring that
    wraps during the verify window would have overwritten live entries no
    rewind can restore. Forbid the configuration instead of corrupting."""
    if (hasattr(mod, "cache_len_for")
            and mod.cache_len_for(cfg, max_len) < max_len):
        raise ValueError(
            f"speculative decoding needs max_len <= sliding_window "
            f"({cfg.sliding_window}) for {cfg.name}: a wrapped KV ring "
            f"cannot be rolled back (got max_len {max_len})")


def _spec_models(params, cfg: ModelConfig, draft_params, draft_cfg):
    """Resolve the (target, drafter) pair; derive the drafter from the
    target checkpoint when none is given. Validates rollback capability."""
    if cfg.family == "ssm":
        raise ValueError("speculative decoding is unavailable for family "
                         "'ssm': the SSD state folds every token "
                         "irreversibly, so rejected drafts can't be rewound")
    if draft_params is None:
        draft_cfg, draft_params = model_api.draft_of(cfg, params)
    else:
        draft_cfg = draft_cfg or cfg
    if draft_cfg.family == "ssm":
        raise ValueError("the speculative DRAFTER can't be family 'ssm': "
                         "its state can't be rewound past rejected drafts")
    if draft_cfg.vocab_size != cfg.vocab_size:
        raise ValueError(f"draft vocab {draft_cfg.vocab_size} != target "
                         f"vocab {cfg.vocab_size}")
    return draft_params, draft_cfg


def _spec_generate(params, prompts: jnp.ndarray, cfg: ModelConfig, *,
                   policy: QuantPolicy, deltas, max_new_tokens: int,
                   temperature: float, seed: int, dtype, matmul_mode: str,
                   attn_mode: str, kv_bits: Optional[int], spec_k: int,
                   draft_params, draft_cfg: Optional[ModelConfig]):
    """Speculative ``generate``: one jitted ``lax.while_loop`` whose body is
    the shared ``spec_decode_tick``; each iteration commits a variable
    1..spec_k+1 tokens per row into a fixed output buffer."""
    from repro.serving.spec import emit_counts, spec_decode_tick
    draft_params, draft_cfg = _spec_models(params, cfg, draft_params,
                                           draft_cfg)
    mod, dmod = get_model(cfg), get_model(draft_cfg)
    b, p = prompts.shape
    # verify scratch-writes up to spec_k+1 positions past the committed
    # stream; size the cache so the last in-budget tick stays in bounds
    max_len = p + max_new_tokens + spec_k
    _no_ring_wrap(mod, cfg, max_len)
    _no_ring_wrap(dmod, draft_cfg, max_len)
    attn_kw = _attn_kwargs(cfg, attn_mode, kv_bits)
    dattn_kw = _attn_kwargs(draft_cfg, attn_mode, kv_bits)
    mkw = dict(policy=policy, deltas=deltas, dtype=dtype,
               matmul_mode=matmul_mode)
    dmkw = dict(policy=policy, deltas=None, dtype=dtype,
                matmul_mode=matmul_mode)
    logits, cache = mod.prefill(params, {"tokens": prompts}, cfg,
                                max_len=max_len, **mkw, **attn_kw["prefill"])
    _, dcache = dmod.prefill(draft_params, {"tokens": prompts}, draft_cfg,
                             max_len=max_len, **dmkw, **dattn_kw["prefill"])
    k0, key = jax.random.split(jax.random.PRNGKey(seed))
    tok0 = _sample(k0, logits[:, 0], temperature)[:, None].astype(jnp.int32)
    if max_new_tokens == 1:
        return jnp.concatenate([prompts, tok0], axis=1)
    # rollback writes per-row lengths; normalize up front so the while_loop
    # carry keeps one structure
    cache["len"] = jnp.broadcast_to(cache["len"], (b,)).astype(jnp.int32)
    dcache["len"] = jnp.broadcast_to(dcache["len"], (b,)).astype(jnp.int32)
    outbuf = jnp.zeros((b, max_new_tokens), jnp.int32).at[:, 0].set(tok0[:, 0])
    budget = jnp.full((b,), max_new_tokens, jnp.int32)
    rows = jnp.arange(b)
    t1 = spec_k + 1

    def cond(carry):
        return jnp.any(carry[3] < max_new_tokens)

    def body(carry):
        cache, dcache, pending, emitted, buf, key = carry
        key, kt = jax.random.split(key)
        active = emitted < max_new_tokens
        cache, dcache, a, out, pending, _ok = spec_decode_tick(
            mod, dmod, params, draft_params, cfg, draft_cfg, cache, dcache,
            pending, active, spec_k=spec_k, temperature=temperature, key=kt,
            mkw=mkw, dmkw=dmkw, attn_kw=attn_kw["decode"],
            dattn_kw=dattn_kw["decode"])
        n, _ = emit_counts(out, a, active=active, emitted=emitted,
                           budget=budget, eos_id=-1)
        for j in range(t1):
            # rows past their window park the write at the OOB sentinel
            idx = jnp.where(j < n, emitted + j, max_new_tokens)
            buf = buf.at[rows, idx].set(out[:, j], mode="drop")
        return cache, dcache, pending, emitted + n, buf, key

    run = jax.jit(lambda c: jax.lax.while_loop(cond, body, c))
    carry = run((cache, dcache, tok0, jnp.ones((b,), jnp.int32), outbuf, key))
    return jnp.concatenate([prompts, carry[4]], axis=1)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # per-request serving stats, filled at drain time: decode ticks this
    # request participated in, and the histogram {window length -> count}
    # of tokens emitted per tick (always {1: n} without speculation; the
    # draft-accept length distribution with it)
    ticks: int = 0
    accept_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # resilience: terminal outcome (one of resilience.STATUS — "ok" unless
    # the request was cancelled/shed/quarantined), absolute expiry in
    # decode ticks (None = no deadline), times preempted, and host
    # wall-clock stamps for submit->finish latency
    status: str = "ok"
    deadline_at: Optional[int] = None
    preemptions: int = 0
    submit_time: float = 0.0
    finish_time: float = 0.0

    @property
    def admit_prompt(self) -> List[int]:
        """What admission prefills: the prompt plus every committed token.
        For a fresh request this is the prompt; a preempted request
        re-enters the bucketed prefill path with its progress folded in,
        which at T=0 greedy makes the continuation token-identical to the
        run it was evicted from."""
        return self.prompt + self.out

    @property
    def remaining(self) -> int:
        """Tokens still owed (the admission budget after preemption)."""
        return self.max_new - len(self.out)


class ServingEngine:
    """Slot-based continuous batching: one jitted decode per tick, all slots.

    ``step()`` = admit + one batched tick (async — tokens stay on device);
    ``drain()`` = bulk host transfer of everything emitted since the last
    drain; ``run_all()`` = drive until queue and slots are empty.

    ``decode_calls`` counts ticks — each is exactly one ``decode_step``
    invocation regardless of the number of active slots — and
    ``prefill_calls`` counts admissions the same way: all queued requests
    sharing a length bucket enter through ONE jitted batched prefill + ONE
    jitted multi-slot admit (asserted by tests/test_engine_batched.py and
    tests/test_engine_bucketed.py).

    Admission order is FIFO by bucket: each admission round serves the
    oldest queued request's bucket, and other same-bucket requests ride
    along (bounded queue-jumping in exchange for batched prefill).
    """

    def __init__(self, params, cfg: ModelConfig, *, policy: QuantPolicy,
                 deltas=None, slots: int = 8, max_len: int = 512,
                 dtype=jnp.bfloat16, temperature: float = 0.0,
                 eos_id: Optional[int] = None, seed: int = 0,
                 drain_every: int = 4, matmul_mode: str = "auto",
                 attn_mode: str = "auto", kv_bits: Optional[int] = None,
                 spec_k: int = 0, draft_params=None,
                 draft_cfg: Optional[ModelConfig] = None,
                 attn_chunk: int = 1024, profile: bool = False,
                 queue_limit: Optional[int] = None,
                 shed_policy: str = "reject",
                 default_deadline: Optional[int] = None,
                 preempt_after: Optional[int] = None,
                 max_ticks: Optional[int] = None, degrade: bool = True,
                 fault_plan: Optional[FaultPlan] = None,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: Optional[int] = None,
                 journal: Optional[str] = None,
                 integrity_every: Optional[int] = None,
                 golden_dir: Optional[str] = None):
        from repro.core.quant_dense import MATMUL_MODES
        if matmul_mode not in MATMUL_MODES:
            raise ValueError(f"matmul_mode must be one of {MATMUL_MODES}, "
                             f"got {matmul_mode!r}")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if shed_policy not in resilience.SHED_POLICIES:
            raise ValueError(f"shed_policy must be one of "
                             f"{resilience.SHED_POLICIES}, got {shed_policy!r}")
        for name, val in (("queue_limit", queue_limit),
                          ("default_deadline", default_deadline),
                          ("preempt_after", preempt_after),
                          ("max_ticks", max_ticks),
                          ("snapshot_every", snapshot_every),
                          ("integrity_every", integrity_every)):
            if val is not None and val < 1:
                raise ValueError(f"{name} must be >= 1 or None, got {val}")
        self.params, self.cfg, self.policy = params, cfg, policy
        self.deltas, self.dtype = deltas, dtype
        self.mod = get_model(cfg)
        self.slots, self.max_len = slots, max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self.drain_every = max(1, drain_every)
        self.matmul_mode = matmul_mode
        # attention dispatch (prefill admission + verify + decode kernels
        # vs ref paths) + int8 KV cache (attention families): kv_bits=8
        # halves cache bytes per slot, i.e. doubles the slots a fixed cache
        # budget can hold — validated (ssm raises) in one place.
        # attn_chunk bounds the ref-mode prefill working set per KV chunk —
        # the long-prompt admission knob when the kernel isn't available
        self.attn_mode, self.kv_bits = attn_mode, kv_bits
        self.attn_chunk = attn_chunk
        self._attn_kw = _attn_kwargs(cfg, attn_mode, kv_bits)
        # shared slot-major cache, allocated ONCE
        self.cache = model_api.init_cache(cfg, slots, max_len, dtype,
                                          per_slot_len=True, kv_bits=kv_bits)
        # speculative decoding: a second slot-major cache for the DRAFTER
        # (by default the qp export of the target's own weights), sharing
        # the engine's serving knobs; spec_accept_rate counters ride drain
        self.spec_k = int(spec_k)
        self._spec = self.spec_k > 0
        self.spec_drafted = 0                 # draft proposals scored
        self.spec_accepted = 0                # proposals the target kept
        if self._spec:
            draft_params, draft_cfg = _spec_models(params, cfg, draft_params,
                                                   draft_cfg)
            _no_ring_wrap(self.mod, cfg, max_len)
            self.draft_params, self.draft_cfg = draft_params, draft_cfg
            self.dmod = get_model(draft_cfg)
            _no_ring_wrap(self.dmod, draft_cfg, max_len)
            self._dattn_kw = _attn_kwargs(draft_cfg, attn_mode, kv_bits)
            self.draft_cache = model_api.init_cache(
                draft_cfg, slots, max_len, dtype, per_slot_len=True,
                kv_bits=kv_bits)
        # per-slot device state
        self._tokens = jnp.zeros((slots, 1), jnp.int32)    # last emitted token
        self._active = jnp.zeros((slots,), bool)
        self._emitted = jnp.zeros((slots,), jnp.int32)     # tokens produced
        self._budget = jnp.zeros((slots,), jnp.int32)      # per-slot max_new
        self._key = jax.random.PRNGKey(seed)
        # the healthy poison bias: ALWAYS a tick input, so fault injection
        # (NaN entries) never changes the traced graph
        self._poison0 = jnp.zeros((slots,), jnp.float32)
        # host-side bookkeeping
        self.queue: List[Request] = []
        self._slot_req: List[Optional[Request]] = [None] * slots
        self._ticks_left = [0] * slots        # deterministic lifetime bound
        self._slot_ticks = [0] * slots        # ticks the current owner held
        # pending records: (tokens (slots, T), counts (slots,), done,
        # owners, accepted-or-None, kind, bad-or-None) — T=1 with counts as
        # the emitted mask for admissions and plain ticks, T=spec_k+1 with
        # true counts for speculative ticks; ``bad`` is the tick's on-device
        # per-slot health flag (None for admissions)
        self._pending: List[Tuple] = []
        self._finished: List[Request] = []    # synced but not yet returned
        self._uid = 0
        self.decode_calls = 0                 # ticks == decode_step calls
        self.prefill_calls = 0                # batched prefill invocations
        # resilience knobs + counters
        self.queue_limit = queue_limit
        self.shed_policy = shed_policy
        self.default_deadline = default_deadline
        self.preempt_after = preempt_after
        self.max_ticks = max_ticks
        self.degrade = degrade
        self._fault_plan = fault_plan
        self._failed_ticks: set = set()       # one-shot fail_ticks consumed
        self._was_spec = False                # degraded out of spec mode
        self.shed_count = 0                   # requests refused/evicted
        self.deadline_miss_count = 0          # requests expired past deadline
        self.preempt_count = 0                # slot evictions (requeued)
        self.poisoned_count = 0               # slots quarantined (non-finite)
        self.fallback_events: List[Tuple[int, str]] = []  # (tick, ladder step)
        self.queue_peak = 0                   # high-water queue depth
        # durability: periodic snapshots + write-ahead journal (see
        # serving.durability) and the weight-store integrity probe + heal
        # (see checkpoint.integrity)
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = snapshot_every
        self.integrity_every = integrity_every
        self.golden_dir = golden_dir
        self.snapshots_written = 0            # snapshot() completions
        self.journal_events = 0               # events appended to the WAL
        self.replayed_events = 0              # journal events replayed in
        self.integrity_probes = 0             # canary passes run
        self.heal_count = 0                   # containers reloaded from golden
        self._last_snapshot_tick = -1         # don't re-snapshot a tick
        self._crashed_ticks: set = set()      # one-shot crash_at_tick consumed
        self._flipped_ticks: set = set()      # one-shot flip_bits consumed
        if journal is not None and not hasattr(journal, "append"):
            from repro.serving.durability import Journal
            journal = Journal(journal)
        self._journal = journal
        self._probe_paths: Optional[List[str]] = None
        if integrity_every is not None:
            self._init_integrity()
        # admission buckets are capped by the cache length: for sliding-
        # window archs the ring slice in prefill is only per-row-exact while
        # padded length <= window, so longer prompts take the solo path
        self._bucket_cap = (self.mod.cache_len_for(cfg, max_len)
                            if hasattr(self.mod, "cache_len_for") else max_len)
        # optional phase timers: wall-clock split between admission (prefill)
        # and decode ticks, for benchmarks. Wrapping blocks on each call's
        # result, so it trades a little async overlap for attribution —
        # off by default.
        self.prefill_secs = 0.0
        self.decode_secs = 0.0
        self._profile = profile
        self._build_jits()

    def _build_jits(self):
        """(Re)build every jitted serving graph from the CURRENT mode knobs
        (spec on/off, matmul_mode, attn_mode). Called at construction and
        again by each degradation-ladder step — the mode kwargs are baked
        into the traced graphs, so changing them means re-jitting.

        Donation: the shared cache(s) are donated (without donation every
        tick and every admission materializes a full second copy of the
        slot-major cache). The small per-slot vectors are NOT donated —
        pending records hold references to pre-tick ``active`` arrays."""
        if self._spec:
            self._tick_fn = jax.jit(self._spec_tick, donate_argnums=(2, 3))
            self._prefill_draft_fn = jax.jit(self._prefill_draft)
            self._admit_draft_fn = jax.jit(
                lambda dc, slot, src: self.dmod.insert_prefill(dc, slot, src),
                donate_argnums=(0,))
            self._admit_draft_many_fn = jax.jit(
                lambda dc, sm, src: self.dmod.insert_prefill_many(dc, sm,
                                                                  src),
                donate_argnums=(0,))
            self._free_draft_fn = jax.jit(
                lambda dc, idx: model_api.free_slots(self.draft_cfg, dc, idx),
                donate_argnums=(0,))
        else:
            self._tick_fn = jax.jit(self._tick, donate_argnums=(1,))
        self._admit_fn = jax.jit(self._admit_device, donate_argnums=(1,))
        self._admit_many_fn = jax.jit(self._admit_many, donate_argnums=(0,))
        self._prefill_fn = jax.jit(self._prefill)
        # slot release (preemption / deadline cancel / quarantine): index
        # vector is always padded to (slots,) with the OOB sentinel so it
        # compiles once regardless of how many rows are freed
        self._free_fn = jax.jit(
            lambda c, idx: model_api.free_slots(self.cfg, c, idx),
            donate_argnums=(0,))
        # the analysis registry's window into this engine: raw jitted fns
        # (recorded BEFORE any profile wrapping), so trace/retrace budgets
        # can be reported from the same place the contract passes run —
        # repro.analysis.contracts.retrace_report reads trace_counts()
        self._jits = {"tick": self._tick_fn, "prefill": self._prefill_fn,
                      "admit": self._admit_fn, "admit_many": self._admit_many_fn,
                      "free": self._free_fn}
        if self._spec:
            self._jits.update(prefill_draft=self._prefill_draft_fn,
                              admit_draft=self._admit_draft_fn,
                              admit_draft_many=self._admit_draft_many_fn)
        if self._profile:
            self._tick_fn = self._timed(self._tick_fn, "decode_secs")
            self._prefill_fn = self._timed(self._prefill_fn, "prefill_secs")
            self._admit_fn = self._timed(self._admit_fn, "prefill_secs")
            self._admit_many_fn = self._timed(self._admit_many_fn,
                                              "prefill_secs")
            if self._spec:
                self._prefill_draft_fn = self._timed(self._prefill_draft_fn,
                                                     "prefill_secs")
                self._admit_draft_fn = self._timed(self._admit_draft_fn,
                                                   "prefill_secs")
                self._admit_draft_many_fn = self._timed(
                    self._admit_draft_many_fn, "prefill_secs")

    # --- degradation ladder (called via resilience.degrade_step) ------------

    def _disable_spec(self):
        """Ladder step 1, spec -> plain: abandon the drafter and its cache
        and re-jit the plain tick. The target stream is unaffected (spec is
        exact — dropping it changes throughput, never tokens): the device
        ``_tokens`` row is the last committed-but-unfed token in both
        modes, so the plain tick resumes mid-request seamlessly. Host
        ``_ticks_left`` stays an upper bound (spec emits >= 1 token per
        tick), and ``_was_spec`` keeps ``_spin_up`` syncing so early
        finishes discovered at drain still free slots promptly."""
        self._spec = False
        self._was_spec = True
        self.spec_k = 0
        self.draft_cache = None
        self._build_jits()

    def _fallback_modes(self):
        """Ladder step 2, kernel -> fallback: route every quantized matmul
        through the fused dequant path and every attention through the
        ref path — the parity oracles the kernels are tested against —
        then re-jit."""
        self.matmul_mode = "dequant"
        self.attn_mode = "ref"
        self._attn_kw = _attn_kwargs(self.cfg, self.attn_mode, self.kv_bits)
        if self._spec:
            self._dattn_kw = _attn_kwargs(self.draft_cfg, self.attn_mode,
                                          self.kv_bits)
        self._build_jits()

    # --- durability: snapshots, write-ahead journal, weight integrity -------

    def _log_event(self, event: Dict[str, Any]):
        """Append one event to the write-ahead journal (no-op without
        one). Every event carries the current tick."""
        if self._journal is not None:
            self._journal.append(dict(event, tick=self.decode_calls))
            self.journal_events += 1

    def snapshot(self, snapshot_dir: Optional[str] = None) -> str:
        """Persist complete engine state (device trees + host bookkeeping)
        as an atomic restore point; see ``serving.durability``."""
        from repro.serving import durability
        d = snapshot_dir or self.snapshot_dir
        if d is None:
            raise ValueError("no snapshot_dir: pass one here or at "
                             "construction")
        return durability.snapshot_engine(self, d)

    def restore(self, snapshot_dir: Optional[str] = None,
                step: Optional[int] = None) -> Dict[str, Any]:
        """Load a snapshot into this (freshly constructed) engine and
        resume exactly where it was taken — token-identical at T=0."""
        from repro.serving import durability
        d = snapshot_dir or self.snapshot_dir
        if d is None:
            raise ValueError("no snapshot_dir: pass one here or at "
                             "construction")
        return durability.restore_engine(self, d, step)

    def recover(self, snapshot_dir: Optional[str] = None,
                journal: Optional[str] = None) -> Dict[str, Any]:
        """Crash recovery: latest snapshot (if any) + journal-tail replay.
        Defaults to the construction-time snapshot dir and journal path."""
        from repro.serving import durability
        jpath = journal or (self._journal.path if self._journal is not None
                            else None)
        return durability.recover(
            self, snapshot_dir=snapshot_dir or self.snapshot_dir,
            journal=jpath)

    def _init_integrity(self):
        """Build the weight-store integrity machinery: the protected-path
        list (packed ``qp``/``q``/``delta`` containers for serve forms,
        every leaf for float masters), a jitted canary-fingerprint probe,
        the golden fingerprint vector, and an independent host-side golden
        copy + CRC manifest to heal from. ``golden_dir`` additionally
        persists the golden store to disk (checkpoint.integrity.save_golden)
        so heals survive the process too."""
        from repro.checkpoint import integrity
        from repro.core.treeutil import tree_get
        paths = integrity.protected_paths(self.params)
        self._probe_paths, probe = integrity.make_probe(self.params, paths)
        self._probe_fn = jax.jit(probe)
        self._golden = {p: np.array(np.asarray(tree_get(self.params, p)))
                        for p in paths}
        self._manifest = integrity.build_manifest(self.params, paths)
        self._golden_fp = np.asarray(self._probe_fn(self.params))
        if self.golden_dir is not None:
            integrity.save_golden(self.golden_dir, self.params, paths)
        self._next_probe = 0

    def _flip_bit(self, path: str, bit: int):
        """Fault injection: XOR one bit of the params leaf at ``path`` —
        a soft error in the resident weight store (``bit`` wraps modulo
        the leaf's bit count). Host round-trip, so the device copy is
        replaced wholesale; the golden copy is independent."""
        from repro.core.treeutil import tree_get, tree_set
        a = np.array(np.asarray(tree_get(self.params, path)))
        raw = a.view(np.uint8).reshape(-1)
        b = int(bit) % (raw.size * 8)
        raw[b // 8] ^= np.uint8(1 << (b % 8))
        self.params = tree_set(self.params, path, jnp.asarray(a))

    def _integrity_probe(self):
        """One canary pass over the protected weight leaves: fingerprint
        vector vs golden. A mismatch names the corrupt container(s) and
        triggers the self-heal."""
        self.integrity_probes += 1
        fps = np.asarray(self._probe_fn(self.params))
        bad = [self._probe_paths[i]
               for i in np.nonzero(fps != self._golden_fp)[0]]
        if bad:
            self._heal(bad)

    def _heal(self, bad_paths: List[str]):
        """Self-heal detected weight corruption: reload each corrupt
        container from the golden copy, confirm the probe matches golden
        again, then REWIND every request whose tokens could have been
        computed against the corrupt store — the suspect window is
        everything since the last clean probe, so resident unfinished
        requests and ok-finished-but-undrained requests are rolled back to
        their prompt and requeued through the normal bucketed admission
        path (same machinery as preemption; at T=0 the recomputed stream
        is the clean stream). Requests already DRAINED between the clean
        probe and detection are the caller-visible at-risk window: probe
        at least as often as you drain to close it."""
        from repro.core.treeutil import tree_set
        self._sync()
        for p in bad_paths:
            self.params = tree_set(self.params, p,
                                   jnp.asarray(self._golden[p]))
            self.heal_count += 1
            self.fallback_events.append((self.decode_calls, f"heal:{p}"))
        fps = np.asarray(self._probe_fn(self.params))
        if not np.array_equal(fps, self._golden_fp):
            raise RuntimeError(
                f"integrity heal failed: {bad_paths} still mismatch the "
                f"golden fingerprints after reload — golden copy corrupt?")
        self._log_event({"e": "heal", "paths": list(bad_paths)})
        victims = [s for s in range(self.slots)
                   if (r := self._slot_req[s]) is not None and not r.done]
        resurrect = [r for r in self._finished if r.status == "ok"]
        self._finished = [r for r in self._finished if r.status != "ok"]
        requeue = [self._slot_req[s] for s in victims] + resurrect
        for s in victims:
            self._release_slot(s)
        for r in sorted(requeue, key=lambda r: r.uid):
            r.out.clear()
            r.done = False
            r.status = "ok"
            r.ticks = 0
            r.accept_hist = {}
            r.finish_time = 0.0
            self.queue.append(r)
        if victims:
            self._deactivate(victims)
            self._free_rows(victims)

    @property
    def spec_accept_rate(self) -> float:
        """Fraction of draft proposals the target accepted (drain-synced;
        the ``prefill_calls``-style speculative counter)."""
        return self.spec_accepted / self.spec_drafted if self.spec_drafted \
            else 0.0

    def _timed(self, fn, attr: str):
        import time

        def wrapped(*a, **kw):
            t0 = time.perf_counter()
            out = jax.block_until_ready(fn(*a, **kw))
            setattr(self, attr,
                    getattr(self, attr) + time.perf_counter() - t0)
            return out
        return wrapped

    # --- static-analysis surface (repro.analysis.contracts) -----------------

    def trace_counts(self) -> Dict[str, int]:
        """{jit name: compiled-trace count} for every jitted serving graph.

        The retrace-budget surface: a healthy engine compiles the tick
        ONCE for an entire run and the bucketed prefill O(#buckets) times.
        ``repro.analysis.contracts.retrace_report`` turns this into the
        same JSON the contract passes report in."""
        return {name: int(fn._cache_size())
                for name, fn in self._jits.items()}

    def contract_points(self, bucket: Optional[int] = None
                        ) -> List[Dict[str, Any]]:
        """The engine's jitted serving graphs, described abstractly for the
        static-analysis passes — NOTHING here executes a graph.

        Each point: ``name``; the unjitted ``fn``; example ``args``
        (engine state plus ShapeDtypeStructs where no live array exists);
        ``donate`` (the argnums the engine donates, for the donation
        pass); ``carry`` (input argnum -> output index for every buffer
        that must be an aval fixed point across ticks — the carry-dtype
        pass); and ``score_dims`` ((T, S) a quadratic score tensor would
        trail with, or None where the pass doesn't apply).

        ``bucket`` is the admission bucket length to describe prefill at
        (default: the largest, i.e. the cache-capped bucket)."""
        bucket = bucket or self._bucket_cap
        key = jax.random.PRNGKey(0)
        sds = jax.ShapeDtypeStruct
        toks = sds((self.slots, bucket), jnp.int32)
        lens = sds((self.slots,), jnp.int32)
        ivec = sds((self.slots,), jnp.int32)
        # abstract batched-prefill outputs feed the admission point
        logits0, src = jax.eval_shape(self._prefill, self.params, toks, lens)
        points: List[Dict[str, Any]] = []
        if self._spec:
            points.append(dict(
                name="spec_tick", fn=self._spec_tick,
                args=(self.params, self.draft_params, self.cache,
                      self.draft_cache, self._tokens, self._active,
                      self._emitted, self._budget, self._poison0, key),
                donate=(2, 3),
                carry={2: 0, 3: 1, 4: 2, 5: 3, 6: 4},
                score_dims=(self.spec_k + 1, self._bucket_cap)))
        else:
            points.append(dict(
                name="decode_tick", fn=self._tick,
                args=(self.params, self.cache, self._tokens, self._active,
                      self._emitted, self._budget, self._poison0, key),
                donate=(1,),
                carry={1: 0, 2: 1, 3: 2, 4: 3},
                score_dims=None))
        points.append(dict(
            name="prefill_bucketed", fn=self._prefill,
            args=(self.params, toks, lens), donate=(), carry={},
            score_dims=(bucket, bucket)))
        points.append(dict(
            name="admit_many", fn=self._admit_many,
            args=(self.cache, self._tokens, self._active, self._emitted,
                  self._budget, ivec, src, logits0, ivec, key),
            donate=(0,),
            carry={0: 0, 1: 1, 2: 2, 3: 3, 4: 4},
            score_dims=None))
        return points

    # --- jitted graph builders (self.mod looked up at trace time so tests can
    # --- instrument the family module's decode_step) ------------------------

    def _mkw(self) -> Dict[str, Any]:
        return dict(policy=self.policy, deltas=self.deltas, dtype=self.dtype,
                    matmul_mode=self.matmul_mode)

    def _eos(self) -> int:
        return -1 if self.eos_id is None else int(self.eos_id)  # -1 never hits

    def _prefill(self, params, toks, lengths=None):
        return self.mod.prefill(params, {"tokens": toks}, self.cfg,
                                max_len=self.max_len, lengths=lengths,
                                attn_chunk=self.attn_chunk,
                                **self._mkw(), **self._attn_kw["prefill"])

    def _dmkw(self) -> Dict[str, Any]:
        # the drafter serves its own (serve-form) params: target deltas
        # don't apply to it
        return dict(policy=self.policy, deltas=None, dtype=self.dtype,
                    matmul_mode=self.matmul_mode)

    def _prefill_draft(self, dparams, toks, lengths=None):
        return self.dmod.prefill(dparams, {"tokens": toks}, self.draft_cfg,
                                 max_len=self.max_len, lengths=lengths,
                                 attn_chunk=self.attn_chunk,
                                 **self._dmkw(), **self._dattn_kw["prefill"])

    def _tick(self, params, cache, tokens, active, emitted, budget, poison,
              key):
        """Advance every active slot one token. Masks computed on-device.

        ``poison`` (slots,) f32 is added to the logits before the health
        check — all-zeros in healthy operation (one add, graph identical),
        NaN entries under fault injection. ``bad`` flags active rows whose
        logits went non-finite: they are frozen exactly like inactive rows
        (token and length held, nothing emitted) and deactivated, and the
        flag rides the pending drain so the host can quarantine them — no
        extra sync, no sampling from a corrupt distribution."""
        logits, new_cache = self.mod.decode_step(params, cache, tokens,
                                                 self.cfg, **self._mkw(),
                                                 **self._attn_kw["decode"])
        logits = logits + poison[:, None, None]
        bad = active & ~jnp.all(jnp.isfinite(logits), axis=(1, 2))
        ok = active & ~bad
        nxt = _sample(key, logits[:, 0], self.temperature).astype(jnp.int32)
        nxt = jnp.where(ok, nxt, tokens[:, 0])       # freeze inactive + bad
        emitted = emitted + ok.astype(jnp.int32)
        done = ok & ((emitted >= budget) | (nxt == self._eos()))
        new_cache["len"] = jnp.where(ok, new_cache["len"], cache["len"])
        return new_cache, nxt[:, None], ok & ~done, emitted, done, bad

    def _spec_tick(self, params, dparams, cache, dcache, tokens, active,
                   emitted, budget, poison, key):
        """Advance every active slot by 1..spec_k+1 tokens: the shared
        ``spec_decode_tick`` core (draft chain -> one multi-token verify ->
        vectorized acceptance -> per-slot rollback of both caches) plus the
        engine's budget/EOS window truncation, all in this ONE jitted call.
        Inactive slots are frozen in-graph: their verify scratch-writes are
        fully rewound and their token/length held, exactly like the plain
        tick's masking. ``poison``/``bad`` mirror the plain tick's health
        check — the core treats a non-finite row as frozen (full rewind,
        nothing committed), so a poisoned slot emits nothing and both
        caches stay clean."""
        from repro.serving.spec import emit_counts, spec_decode_tick
        cache, dcache, a, out, new_tok, row_ok = spec_decode_tick(
            self.mod, self.dmod, params, dparams, self.cfg, self.draft_cfg,
            cache, dcache, tokens, active, spec_k=self.spec_k,
            temperature=self.temperature, key=key, mkw=self._mkw(),
            dmkw=self._dmkw(), attn_kw=self._attn_kw["decode"],
            dattn_kw=self._dattn_kw["decode"], logit_bias=poison)
        bad = active & ~row_ok
        eff = active & ~bad
        n, done = emit_counts(out, a, active=eff, emitted=emitted,
                              budget=budget, eos_id=self._eos())
        return (cache, dcache, new_tok, eff & ~done, emitted + n, done,
                out, n, jnp.where(eff, a, 0), bad)

    def _admit_device(self, params, cache, tokens, active, emitted, budget,
                      slot, src, logits0, req_budget, key):
        """Insert a prefilled request into ``slot`` and sample its first
        token. ``slot``/``req_budget`` traced -> compiles once."""
        cache = self.mod.insert_prefill(cache, slot, src)
        t0 = _sample(key, logits0[:, 0], self.temperature).astype(jnp.int32)
        tokens = jax.lax.dynamic_update_slice(tokens, t0[:, None], (slot, 0))
        # the prefill sample already counts: a max_new==1 request (or an
        # immediate EOS) never becomes active
        act0 = (req_budget > 1) & (t0[0] != self._eos())
        active = jax.lax.dynamic_update_slice(active, act0[None], (slot,))
        emitted = jax.lax.dynamic_update_slice(
            emitted, jnp.ones((1,), jnp.int32), (slot,))
        budget = jax.lax.dynamic_update_slice(budget, req_budget[None], (slot,))
        return cache, tokens, active, emitted, budget

    def _admit_many(self, cache, tokens, active, emitted, budget, slot_map,
                    src, logits0, req_budget, key):
        """Insert an N-row batched prefill into slots ``slot_map`` and
        sample every row's first token — ONE jitted call for the whole
        admission round. Rows with ``slot_map[i] >= slots`` are batch
        padding: every scatter drops them (JAX OOB-scatter semantics)."""
        cache = self.mod.insert_prefill_many(cache, slot_map, src)
        t0 = _sample(key, logits0[:, 0], self.temperature).astype(jnp.int32)
        tokens = tokens.at[slot_map].set(t0[:, None], mode="drop")
        # the prefill sample already counts: a max_new==1 request (or an
        # immediate EOS) never becomes active
        act0 = (req_budget > 1) & (t0 != self._eos())
        active = active.at[slot_map].set(act0, mode="drop")
        emitted = emitted.at[slot_map].set(jnp.ones_like(req_budget),
                                           mode="drop")
        budget = budget.at[slot_map].set(req_budget, mode="drop")
        return cache, tokens, active, emitted, budget

    # --- public API ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new: int = 16,
               deadline_ticks: Optional[int] = None) -> SubmitOutcome:
        """Enqueue a request. Malformed requests raise ``SubmitRejected``
        (a ValueError with a machine-readable ``reason``); well-formed
        requests return a ``SubmitOutcome`` — the uid as an int (legacy
        callers unchanged) when admitted, falsy with
        ``reason='queue_full'`` when bounded admission sheds it.

        ``deadline_ticks`` (or the engine's ``default_deadline``) sets an
        absolute expiry ``decode_calls + deadline_ticks``: a request not
        finished by then is cancelled — mid-stream if resident (slot freed,
        partial output returned with ``status='deadline'``), or straight
        from the queue if it never got a slot."""
        if len(prompt) == 0:
            # a [] prompt would build a (1, 0) token array and crash deep
            # inside prefill; reject it where the caller can see why
            raise SubmitRejected("empty_prompt",
                                 "prompt must contain at least one token")
        if max_new < 1:
            raise SubmitRejected("bad_max_new",
                                 f"max_new must be >= 1, got {max_new}")
        if len(prompt) + max_new + self.spec_k > self.max_len:
            # speculative verify scratch-writes up to spec_k positions past
            # the final committed token; reserve that headroom in the cache
            total = len(prompt) + max_new + self.spec_k
            label = (f"prompt+max_new+spec_k ({len(prompt)}+{max_new}"
                     f"+{self.spec_k}={total})" if self._spec
                     else f"prompt+max_new ({total})")
            raise SubmitRejected(
                "too_long",
                f"{label} exceeds engine max_len {self.max_len}")
        if deadline_ticks is not None and deadline_ticks < 1:
            raise SubmitRejected(
                "bad_deadline",
                f"deadline_ticks must be >= 1, got {deadline_ticks}")
        shed: Tuple[int, ...] = ()
        if self.queue_limit is not None and len(self.queue) >= self.queue_limit:
            self.shed_count += 1
            if self.shed_policy == "reject":
                self._log_event({"e": "shed", "uid": None,
                                 "reason": "queue_full"})
                return SubmitOutcome(0, accepted=False, reason="queue_full")
            victim = self.queue.pop(0)               # drop_oldest
            self._log_event({"e": "shed", "uid": victim.uid,
                             "reason": "queue_full"})
            self._finish(victim, "shed")
            shed = (victim.uid,)
        self._uid += 1
        dl = deadline_ticks if deadline_ticks is not None \
            else self.default_deadline
        req = Request(self._uid, list(prompt), max_new,
                      deadline_at=(self.decode_calls + dl) if dl else None,
                      submit_time=time.perf_counter())
        # write-ahead: the acceptance is durable before the queue sees it,
        # so a crash after this line can always replay the request
        self._log_event({"e": "submit", "uid": req.uid, "prompt": req.prompt,
                         "max_new": max_new, "deadline_at": req.deadline_at})
        self.queue.append(req)
        self.queue_peak = max(self.queue_peak, len(self.queue))
        return SubmitOutcome(self._uid, accepted=True, shed=shed)

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def _bucket_len(self, plen: int) -> int:
        """Admission bucket: next power of two >= plen (floor _MIN_BUCKET),
        capped at the cache length — a small static set, so jitted prefill
        re-traces O(#buckets) times under arbitrary mixed prompt lengths."""
        return min(max(_MIN_BUCKET, 1 << (plen - 1).bit_length()),
                   self._bucket_cap)

    def _free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if self._slot_req[s] is None]

    def _occupied(self) -> bool:
        return any(r is not None for r in self._slot_req)

    def _spin_up(self):
        """Admit queued requests into free slots, one length bucket at a
        time: every same-bucket queued request enters through ONE jitted
        batched prefill + ONE jitted multi-slot admit. When the queue has
        waiters and no slot is free, ``preempt_after`` lets a slot held
        longer than its fair-share tick budget be preempted (committed
        tokens snapshotted host-side, row freed, request requeued at the
        back — it re-enters right here through the same bucketed path).

        Admission keys on ``admit_prompt`` (prompt + committed tokens), so
        preempted requests bucket by their grown effective prompt."""
        if (self._fault_plan is not None
                and self._fault_plan.delays_admission_at(self.decode_calls)):
            return                            # injected admission stall
        if not self.queue:
            return
        free = self._free_slots()
        if not free and (self.eos_id is not None or self._spec
                         or self._was_spec):
            # an EOS — or, with speculation, a multi-token burst through the
            # budget — may have freed a slot we haven't observed yet; _sync
            # keeps the finished requests queued for the next drain()
            self._sync()
            free = self._free_slots()
        if not free and self.preempt_after is not None:
            victims = [s for s in range(self.slots)
                       if self._slot_req[s] is not None
                       and self._slot_ticks[s] >= self.preempt_after]
            if victims:
                # never preempt more slots than there are waiters
                self._preempt(victims[:len(self.queue)])
                free = self._free_slots()
        while self.queue and free:
            head = self.queue[0]
            if len(head.admit_prompt) > self._bucket_cap:
                # sliding-window ring overflow: padded per-row ring alignment
                # is undefined, so this prompt takes the exact solo path
                self._admit_solo(free.pop(0), self.queue.pop(0))
                continue
            bucket = self._bucket_len(len(head.admit_prompt))
            batch: List[Request] = []
            rest: List[Request] = []
            for r in self.queue:
                if (len(batch) < len(free)
                        and len(r.admit_prompt) <= self._bucket_cap
                        and self._bucket_len(len(r.admit_prompt)) == bucket):
                    batch.append(r)
                else:
                    rest.append(r)
            self.queue = rest
            slot_ids = [free.pop(0) for _ in batch]
            self._admit_batch(slot_ids, batch, bucket)

    # --- slot release + resilience helpers ----------------------------------

    def _finish(self, req: Request, status: str):
        """Terminal bookkeeping shared by every way a request ends."""
        req.status = status
        req.done = True
        req.finish_time = time.perf_counter()
        self._log_event({"e": "finish", "uid": req.uid, "status": status,
                         "n_out": len(req.out)})
        self._finished.append(req)

    def _pad_slots(self, slot_list: List[int]) -> jnp.ndarray:
        """Slot indices padded to a fixed (slots,) shape with the OOB
        sentinel (dropped by every scatter) — varying release counts never
        retrace."""
        idx = np.full((self.slots,), self.slots, np.int32)
        idx[:len(slot_list)] = slot_list
        return jnp.asarray(idx)

    def _deactivate(self, slot_list: List[int]):
        self._active = self._active.at[self._pad_slots(slot_list)].set(
            False, mode="drop")

    def _free_rows(self, slot_list: List[int]):
        """Zero the cache rows of released slots (and the drafter's) back
        to the freshly-allocated state — stale KV/SSM state (or NaN
        contamination) never leaks into the slot's next tenant."""
        idx = self._pad_slots(slot_list)
        self.cache = self._free_fn(self.cache, idx)
        if self._spec:
            self.draft_cache = self._free_draft_fn(self.draft_cache, idx)

    def _release_slot(self, s: int):
        self._slot_req[s] = None
        self._ticks_left[s] = 0
        self._slot_ticks[s] = 0

    def _preempt(self, victims: List[int]):
        """Preempt ``victims``: sync so every committed token is
        attributed, snapshot prompt+out host-side, requeue at the BACK of
        the queue (waiters at the front get the freed slots), and zero the
        device rows. The request re-enters through the normal bucketed
        prefill with its committed tokens folded into the prompt — at T=0
        greedy the continuation is token-identical to the run it left."""
        self._sync()
        live: List[int] = []
        for s in victims:
            req = self._slot_req[s]
            if req is None or req.done:       # sync finished it already
                continue
            live.append(s)
            req.preemptions += 1
            self.preempt_count += 1
            self._release_slot(s)
            self.queue.append(req)
        if live:
            self._deactivate(live)
            self._free_rows(live)

    def _expire_deadlines(self):
        """Cancel every request past its deadline: queued requests are
        dropped before ever holding a slot; resident requests are synced
        first (their partial output is attributed and returned), then
        cancelled mid-stream — device row deactivated and zeroed."""
        now = self.decode_calls
        q_exp = [r for r in self.queue
                 if r.deadline_at is not None and now >= r.deadline_at]
        s_exp = [s for s in range(self.slots)
                 if (r := self._slot_req[s]) is not None
                 and r.deadline_at is not None and now >= r.deadline_at]
        if not q_exp and not s_exp:
            return
        self._sync()          # attribute partial output before cancelling
        for r in q_exp:
            self.queue.remove(r)
            self.deadline_miss_count += 1
            self._finish(r, "deadline")
        cancelled: List[int] = []
        for s in s_exp:
            r = self._slot_req[s]
            if r is None or r.done:           # sync finished/freed it
                continue
            cancelled.append(s)
            self.deadline_miss_count += 1
            self._finish(r, "deadline")
            self._release_slot(s)
        if cancelled:
            self._deactivate(cancelled)
            self._free_rows(cancelled)

    def _poison_for_tick(self) -> jnp.ndarray:
        """The tick's logit-bias vector: the cached all-zeros array in
        healthy operation (same buffer every tick — no retrace, one add in
        the graph), NaN entries for slots the fault plan poisons now."""
        fp = self._fault_plan
        if fp is not None:
            bad = [s for s in fp.nan_slots_at(self.decode_calls)
                   if s < self.slots]
            if bad:
                v = np.zeros((self.slots,), np.float32)
                v[bad] = np.nan
                return jnp.asarray(v)
        return self._poison0

    def _diagnostics(self) -> Dict[str, Any]:
        """The watchdog's dump: what is queued, who holds which slot and
        for how much longer, and every resilience counter."""
        return {
            "queue_depth": len(self.queue),
            "queued_uids": [r.uid for r in self.queue],
            "active_slots": [s for s in range(self.slots)
                             if self._slot_req[s] is not None],
            "slots": [{"slot": s, "uid": r.uid,
                       "ticks_left": self._ticks_left[s],
                       "held_ticks": self._slot_ticks[s]}
                      for s in range(self.slots)
                      if (r := self._slot_req[s]) is not None],
            "decode_calls": self.decode_calls,
            "prefill_calls": self.prefill_calls,
            "shed_count": self.shed_count,
            "deadline_miss_count": self.deadline_miss_count,
            "preempt_count": self.preempt_count,
            "poisoned_count": self.poisoned_count,
            "fallback_events": list(self.fallback_events),
            "snapshots_written": self.snapshots_written,
            "journal_events": self.journal_events,
            "replayed_events": self.replayed_events,
            "integrity_probes": self.integrity_probes,
            "heal_count": self.heal_count,
        }

    def _admit_batch(self, slot_ids: List[int], reqs: List[Request],
                     bucket: int):
        """Prefill ``reqs`` (all in one length bucket) right-padded to
        ``bucket`` in a single jitted call, then scatter them into
        ``slot_ids`` with a single jitted admit. The batch dimension is
        pinned to ``slots`` (dummy rows carry an out-of-range slot-map
        entry, so every scatter drops them): jit re-traces are keyed only
        on the bucket length."""
        n = self.slots
        toks = np.zeros((n, bucket), np.int32)
        lens = np.ones((n,), np.int32)            # dummy rows: valid length 1
        slot_map = np.full((n,), self.slots, np.int32)   # OOB -> dropped
        budgets = np.ones((n,), np.int32)
        for i, (s, r) in enumerate(zip(slot_ids, reqs)):
            ap = r.admit_prompt
            toks[i, :len(ap)] = ap
            lens[i], slot_map[i], budgets[i] = len(ap), s, r.remaining
        logits0, src = self._prefill_fn(self.params, jnp.asarray(toks),
                                        jnp.asarray(lens))
        self.prefill_calls += 1
        self._key, k = jax.random.split(self._key)
        (self.cache, self._tokens, self._active, self._emitted,
         self._budget) = self._admit_many_fn(
            self.cache, self._tokens, self._active, self._emitted,
            self._budget, jnp.asarray(slot_map), src, logits0,
            jnp.asarray(budgets), k)
        if self._spec:
            # the drafter needs the prompt in ITS cache too (logits unused:
            # the target samples every committed token). Rides the same
            # admission round — prefill_calls counts rounds, not models.
            _, dsrc = self._prefill_draft_fn(self.draft_params,
                                             jnp.asarray(toks),
                                             jnp.asarray(lens))
            self.draft_cache = self._admit_draft_many_fn(
                self.draft_cache, jnp.asarray(slot_map), dsrc)
        self._record_admitted(slot_ids, reqs)

    def _admit_solo(self, slot: int, req: Request):
        """Exact-length single-request admission (prompts longer than the
        bucket cap, i.e. past the sliding-window ring)."""
        toks = jnp.asarray([req.admit_prompt], jnp.int32)
        logits0, src = self._prefill_fn(self.params, toks)
        self.prefill_calls += 1
        self._key, k = jax.random.split(self._key)
        (self.cache, self._tokens, self._active, self._emitted,
         self._budget) = self._admit_fn(
            self.params, self.cache, self._tokens, self._active,
            self._emitted, self._budget, jnp.asarray(slot, jnp.int32),
            src, logits0, jnp.asarray(req.remaining, jnp.int32), k)
        if self._spec:
            _, dsrc = self._prefill_draft_fn(self.draft_params, toks)
            self.draft_cache = self._admit_draft_fn(
                self.draft_cache, jnp.asarray(slot, jnp.int32), dsrc)
        self._record_admitted([slot], [req])

    def _record_admitted(self, slot_ids: List[int], reqs: List[Request]):
        """Post-admit bookkeeping shared by the batched and solo paths:
        record the prefill tokens — emitted by the admitted slots only, done
        iff a request never became active (max_new == 1 / instant EOS) —
        and release slots whose lifetime is already over (drain finishes
        them)."""
        self._log_event({"e": "admit", "uids": [r.uid for r in reqs],
                         "slots": list(slot_ids)})
        mask_np = np.zeros((self.slots,), bool)
        for s, r in zip(slot_ids, reqs):
            self._slot_req[s] = r
            self._ticks_left[s] = r.remaining - 1
            self._slot_ticks[s] = 0
            mask_np[s] = True
        mask = jnp.asarray(mask_np)
        self._pending.append((self._tokens, mask, mask & ~self._active,
                              tuple(self._slot_req), None, "admit", None))
        for s in slot_ids:
            if self._ticks_left[s] <= 0:
                self._slot_req[s] = None

    def step(self):
        """Expire deadlines, admit, then advance ALL active slots with ONE
        jitted decode call (speculative mode: up to spec_k+1 tokens per
        slot, still one call). A failed tick call is retried down the
        degradation ladder (spec -> plain, kernel -> fallback) before the
        failure propagates.

        Asynchronous: emitted tokens stay on device until ``drain()``.

        Durability hooks ride the tick boundary: an injected
        ``crash_at_tick`` raises :class:`~repro.serving.resilience.
        InjectedCrash` FIRST (before anything else — a killed process does
        nothing else, and the degradation ladder never sees it), injected
        ``flip_bits`` corrupt the resident weight store, the integrity
        probe then gets its chance to detect + heal, and a completed tick
        lands a periodic snapshot (``snapshot_every``).
        """
        fp = self._fault_plan
        if (fp is not None and fp.crashes_at(self.decode_calls)
                and self.decode_calls not in self._crashed_ticks):
            self._crashed_ticks.add(self.decode_calls)
            raise resilience.InjectedCrash(
                f"injected process kill at decode tick {self.decode_calls}")
        if fp is not None and self.decode_calls not in self._flipped_ticks:
            flips = fp.flips_at(self.decode_calls)
            if flips:
                self._flipped_ticks.add(self.decode_calls)
                for path, bit in flips:
                    self._flip_bit(path, bit)
        if (self._probe_paths is not None
                and self.decode_calls >= self._next_probe):
            self._next_probe = self.decode_calls + self.integrity_every
            self._integrity_probe()
        self._expire_deadlines()
        self._spin_up()
        if not self._occupied():
            return
        emitted_mask = self._active                  # who emits this tick
        owners = tuple(self._slot_req)
        poison = self._poison_for_tick()
        self._key, k = jax.random.split(self._key)
        self._dispatch_tick(owners, emitted_mask, poison, k)
        self.decode_calls += 1
        for s in range(self.slots):
            if self._slot_req[s] is not None:
                self._slot_ticks[s] += 1
                self._ticks_left[s] -= 1
                if self._ticks_left[s] <= 0:
                    self._release_slot(s)        # budget exhausted this tick
        if (self.snapshot_dir is not None and self.snapshot_every is not None
                and self.decode_calls % self.snapshot_every == 0
                and self.decode_calls != self._last_snapshot_tick):
            self.snapshot()

    def _call_tick(self, poison, k):
        """One jitted tick on the CURRENT graph (spec or plain), with the
        fault plan's injected failures raised IN PLACE of the call — before
        it, so donated buffers are intact and a ladder retry sees
        consistent state. Each planned failure fires once."""
        fp = self._fault_plan
        if (fp is not None and fp.fails_at(self.decode_calls)
                and self.decode_calls not in self._failed_ticks):
            self._failed_ticks.add(self.decode_calls)
            raise resilience.InjectedFault(
                f"injected tick failure at decode tick {self.decode_calls}")
        if self._spec:
            return self._tick_fn(
                self.params, self.draft_params, self.cache, self.draft_cache,
                self._tokens, self._active, self._emitted, self._budget,
                poison, k)
        return self._tick_fn(self.params, self.cache, self._tokens,
                             self._active, self._emitted, self._budget,
                             poison, k)

    def _dispatch_tick(self, owners, emitted_mask, poison, k):
        """Run one tick, walking the degradation ladder on failure: each
        retry first applies ``resilience.degrade_step`` (spec -> plain,
        then kernel -> fallback graphs); with the ladder exhausted, an
        injected (transient) fault still earns one same-graph retry, and
        anything else propagates."""
        attempts = 0
        while True:
            spec_call = self._spec
            try:
                out = self._call_tick(poison, k)
                break
            except Exception as e:
                attempts += 1
                label = resilience.degrade_step(self) if self.degrade else None
                if (label is None and attempts < 3
                        and isinstance(e, resilience.InjectedFault)):
                    label = "retry"
                if label is None or attempts >= 4:
                    raise
                self.fallback_events.append((self.decode_calls, label))
        if spec_call:
            (self.cache, self.draft_cache, self._tokens, self._active,
             self._emitted, done, out_toks, counts, accepted, bad) = out
            self._pending.append((out_toks, counts, done, owners, accepted,
                                  "tick", bad))
        else:
            (self.cache, self._tokens, self._active, self._emitted,
             done, bad) = out
            self._pending.append((self._tokens, emitted_mask, done, owners,
                                  None, "tick", bad))

    def _sync(self):
        """Bulk-sync everything emitted since the last sync; attribute
        tokens to requests via per-tick owner snapshots. Newly finished
        requests accumulate in ``_finished`` until ``drain()`` hands them
        out (an internal sync must never lose them).

        Records carry variable per-slot token counts (speculative ticks emit
        1..spec_k+1 tokens per slot); ONE ``device_get`` moves every pending
        array to the host, so the async no-per-token-sync property holds in
        both modes. Per-request tick/accept-histogram stats and the engine's
        ``spec_drafted``/``spec_accepted`` counters are folded in here."""
        if not self._pending:
            return
        moved = jax.device_get([(toks, counts, done,
                                 () if acc is None else acc,
                                 () if bad is None else bad)
                                for toks, counts, done, _, acc, _, bad
                                in self._pending])
        quarantined: List[int] = []
        committed: Dict[int, int] = {}        # uid -> tokens attributed now
        for (toks, counts, done, acc, bad), (_, _, _, owners, _, kind, _) \
                in zip(moved, self._pending):
            badv = None if isinstance(bad, tuple) else np.asarray(bad)
            for s in np.nonzero(counts)[0]:
                if badv is not None and badv[s]:
                    continue       # poisoned row: frozen in-graph, no tokens
                req = owners[s]
                if req is not None:
                    n = int(counts[s])
                    req.out.extend(int(x) for x in toks[s, :n])
                    committed[req.uid] = committed.get(req.uid, 0) + n
                    if kind == "tick":
                        req.ticks += 1
                        req.accept_hist[n] = req.accept_hist.get(n, 0) + 1
            if not isinstance(acc, tuple):            # speculative tick
                live = np.asarray(counts) > 0
                # k from the record's window width: still right for records
                # drained after a mid-run spec->plain degrade
                self.spec_drafted += int((toks.shape[1] - 1) * live.sum())
                self.spec_accepted += int(np.asarray(acc)[live].sum())
            for s in np.nonzero(done)[0]:
                req = owners[s]
                if req is not None and not req.done:
                    self._finish(req, "ok")
                    if self._slot_req[s] is req:   # early EOS: free the slot
                        self._release_slot(s)
            if badv is not None:
                for s in np.nonzero(badv)[0]:
                    req = owners[s]
                    if req is not None and not req.done:
                        self.poisoned_count += 1
                        self._finish(req, "poisoned")
                        if self._slot_req[s] is req:
                            self._release_slot(s)
                            quarantined.append(s)
        self._pending.clear()
        if self._journal is not None:
            for uid in sorted(committed):
                self._log_event({"e": "commit", "uid": uid,
                                 "n": committed[uid]})
        if quarantined:
            # the tick already deactivated poisoned rows on-device; zeroing
            # them keeps contaminated state out of the slot's next tenant
            self._free_rows(sorted(set(quarantined)))

    def drain(self) -> List[Request]:
        """Sync pending emissions and return every request that finished
        since the last ``drain()`` call."""
        self._sync()
        out, self._finished = self._finished, []
        return out

    def run_all(self, max_ticks: Optional[int] = None) -> List[Request]:
        """Drive until queue and slots are empty.

        ``max_ticks`` (default: the engine's ``max_ticks``; None = no
        watchdog) bounds the number of driver iterations — a wedged engine
        (admission stalled, a slot that never finishes) raises
        :class:`~repro.serving.resilience.WatchdogExpired` carrying a
        diagnostic dump (queue depth, active slots, per-slot tick budgets,
        every resilience counter) instead of spinning forever. Requests
        already finished stay drainable after the raise."""
        if max_ticks is None:
            max_ticks = self.max_ticks
        done: List[Request] = []
        iters = 0
        while self.queue or self._occupied():
            if max_ticks is not None and iters >= max_ticks:
                self._sync()
                # hand the already-finished work back through drain()
                self._finished = done + self._finished
                diag = self._diagnostics()
                raise WatchdogExpired(
                    f"run_all exceeded max_ticks={max_ticks} with work "
                    f"still pending: queue depth {diag['queue_depth']}, "
                    f"active slots {diag['active_slots']}, per-slot state "
                    f"{diag['slots']}", diag)
            self.step()
            iters += 1
            # periodic drain bounds the pending-buffer growth (one record
            # per tick) and, with EOS, discovers freed slots early
            if self.decode_calls % self.drain_every == 0:
                done.extend(self.drain())
        done.extend(self.drain())
        return done
