"""Batched serving engine: prefill -> decode loop with greedy/temperature
sampling, packed-weight option (the paper's deployed form), and a simple
continuous-batching slot manager for request streams.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.precision import QuantPolicy
from repro.models import get_model

__all__ = ["generate", "ServingEngine"]


def _sample(key, logits: jnp.ndarray, temperature: float) -> jnp.ndarray:
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def generate(params, prompts: jnp.ndarray, cfg: ModelConfig, *,
             policy: QuantPolicy, deltas=None, max_new_tokens: int = 32,
             temperature: float = 0.0, seed: int = 0,
             dtype=jnp.bfloat16) -> jnp.ndarray:
    """prompts (B, P) int32 -> (B, P + max_new_tokens). jit-compiled decode."""
    mod = get_model(cfg)
    b, p = prompts.shape
    max_len = p + max_new_tokens
    logits, cache = mod.prefill(params, {"tokens": prompts}, cfg,
                                policy=policy, deltas=deltas, dtype=dtype,
                                max_len=max_len)
    key = jax.random.PRNGKey(seed)

    @jax.jit
    def step(carry, k):
        cache, tok = carry
        logits, cache = mod.decode_step(params, cache, tok, cfg, policy=policy,
                                        deltas=deltas, dtype=dtype)
        nxt = _sample(k, logits[:, 0], temperature)[:, None].astype(jnp.int32)
        return (cache, nxt), nxt

    tok0 = _sample(key, logits[:, 0], temperature)[:, None].astype(jnp.int32)
    (cache, _), toks = jax.lax.scan(step, (cache, tok0),
                                    jax.random.split(key, max_new_tokens - 1))
    out = jnp.concatenate([prompts, tok0, toks[:, :, 0].T], axis=1)
    return out


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch.

    Requests join free slots after a (single-request) prefill; every decode
    step advances all active slots at once — the standard large-scale decode
    pattern (the batch matmul amortizes the packed-weight streaming, which is
    exactly the paper's throughput argument: weights are read once per step
    regardless of batch size).
    """

    def __init__(self, params, cfg: ModelConfig, *, policy: QuantPolicy,
                 deltas=None, slots: int = 8, max_len: int = 512,
                 dtype=jnp.bfloat16):
        self.params, self.cfg, self.policy = params, cfg, policy
        self.deltas, self.dtype = deltas, dtype
        self.mod = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.active: Dict[int, Request] = {}
        self.queue: List[Request] = []
        self._uid = 0

    def submit(self, prompt: List[int], max_new: int = 16) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, prompt, max_new))
        return self._uid

    def _spin_up(self):
        while self.queue and len(self.active) < self.slots:
            req = self.queue.pop(0)
            toks = jnp.asarray([req.prompt], jnp.int32)
            logits, cache = self.mod.prefill(
                self.params, {"tokens": toks}, self.cfg, policy=self.policy,
                deltas=self.deltas, dtype=self.dtype, max_len=self.max_len)
            nxt = int(jnp.argmax(logits[0, 0]))
            req.out.append(nxt)
            slot = min(set(range(self.slots)) - set(self.active), default=None)
            self.active[slot] = req
            req._cache = cache            # per-slot cache (single-row batch)

    def step(self):
        """One decode step across all active slots."""
        self._spin_up()
        finished = []
        for slot, req in list(self.active.items()):
            tok = jnp.asarray([[req.out[-1]]], jnp.int32)
            logits, req._cache = self.mod.decode_step(
                self.params, req._cache, tok, self.cfg, policy=self.policy,
                deltas=self.deltas, dtype=self.dtype)
            req.out.append(int(jnp.argmax(logits[0, 0])))
            if len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                del self.active[slot]
        return finished

    def run_all(self) -> List[Request]:
        done: List[Request] = []
        while self.queue or self.active:
            done.extend(self.step())
        return done
