"""Draft phase: K proposals from the quantized drafter, one ``lax.scan``.

The drafter runs the EXISTING single-token decode path (fused Pallas
qmatvec/qmatmul + decode-attention kernels for ``qp`` params), so drafting
inherits every serving optimization; the scan makes the whole chain one
traced region inside the engine's jitted tick — no per-draft-token host
dispatch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["draft_chain"]


def draft_chain(mod, draft_params, dcache, pending: jnp.ndarray, dcfg, *,
                spec_k: int, temperature: float, key,
                mkw: dict, attn_kw: Optional[dict] = None):
    """Run ``spec_k + 1`` drafter decode steps from the committed stream.

    ``pending`` (B, 1): the last sampled-but-not-yet-fed token. Step ``j``
    consumes the previous token and samples proposal ``x_{j+1}``; the chain
    deliberately runs ONE step past the K proposals so the drafter's cache
    also holds the entry for its own last proposal ``x_K`` — otherwise an
    all-accepted tick would leave the draft cache one entry short of the
    committed stream (the classic drafter-lag bug). The final step's sample
    is discarded.

    Returns ``(dcache, trajectory, drafts (B, K), draft_logits (B, K, V))``
    where ``trajectory`` stacks the drafter's rollback state snapshots
    (``mod.spec_state_snapshot``) with the pre-draft state first — None for
    stateless-KV drafters.
    """
    snap0 = mod.spec_state_snapshot(dcache)
    keys = jax.random.split(key, spec_k + 1)

    def step(carry, k_):
        dc, cur = carry
        logits, dc = mod.decode_step(draft_params, dc, cur, dcfg, **mkw,
                                     **(attn_kw or {}))
        lg = logits[:, 0]
        if temperature == 0.0:
            nxt = jnp.argmax(lg, axis=-1)
        else:
            nxt = jax.random.categorical(k_, lg / temperature, axis=-1)
        nxt = nxt[:, None].astype(jnp.int32)
        return (dc, nxt), (lg, nxt[:, 0], mod.spec_state_snapshot(dc))

    (dcache, _), (logits, toks, snaps) = jax.lax.scan(
        step, (dcache, pending), keys)
    trajectory = None
    if snap0 is not None:
        trajectory = jax.tree_util.tree_map(
            lambda init, s: jnp.concatenate([init[None], s]), snap0, snaps)
    drafts = toks[:spec_k].T                                   # (B, K)
    draft_logits = logits[:spec_k].transpose(1, 0, 2)          # (B, K, V)
    return dcache, trajectory, drafts, draft_logits
