"""Acceptance-rejection sampling for speculative decoding — exact in the
target distribution (Leviathan et al. / Chen et al. speculative sampling):

  * T=0: draft ``x_i`` is accepted iff it equals the target argmax after
    consuming ``x_1..x_{i-1}``; the first mismatch position emits the target
    argmax instead. The committed stream is therefore token-identical to
    non-speculative greedy decode.
  * T>0: draft ``x_i`` is accepted with probability
    ``min(1, p_t(x_i) / p_d(x_i))``; on rejection the replacement token is
    drawn from the residual ``norm(max(p_t - p_d, 0))``, and when all K
    drafts are accepted a bonus token is drawn from the target's K+1-th
    distribution. Marginally each emitted token is distributed exactly as
    the target model's ``softmax(logits / T)`` — verified empirically by
    tests/test_spec_accept.py.

Everything is vectorized over the batch/slot dimension: an engine tick
computes acceptance for every slot in-graph, with no host sync.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["spec_accept", "emit_counts"]

_TINY = 1e-30


def spec_accept(draft_toks: jnp.ndarray, draft_logits: jnp.ndarray,
                target_logits: jnp.ndarray, *, temperature: float,
                key) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Vectorized acceptance-rejection over a (B, K) draft window.

    ``draft_toks`` (B, K) int32; ``draft_logits`` (B, K, V) the drafter's
    logits that produced them; ``target_logits`` (B, K+1, V) from
    ``verify_step`` (position ``i`` = target distribution after consuming
    draft ``i``, position K = the bonus distribution).

    Returns ``(accept_len (B,), out_tokens (B, K+1), next_pending (B,))``:
    ``accept_len`` = a in [0, K] accepted drafts; ``out_tokens[:, :a+1]``
    is the emitted window (accepted drafts + one correction/bonus token,
    which is also ``next_pending`` — the next tick's input).
    """
    b, k = draft_toks.shape
    steps = jnp.arange(k + 1)
    if temperature == 0.0:
        t_hat = jnp.argmax(target_logits, axis=-1)             # (B, K+1)
        match = draft_toks == t_hat[:, :k]
        a = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
        extra = jnp.take_along_axis(t_hat, a[:, None], axis=1)[:, 0]
    else:
        k_acc, k_res = jax.random.split(key)
        pt = jax.nn.softmax(target_logits / temperature, axis=-1)
        pd = jax.nn.softmax(draft_logits / temperature, axis=-1)
        ptx = jnp.take_along_axis(pt[:, :k], draft_toks[..., None],
                                  axis=-1)[..., 0]             # (B, K)
        pdx = jnp.take_along_axis(pd, draft_toks[..., None],
                                  axis=-1)[..., 0]
        u = jax.random.uniform(k_acc, (b, k))
        # accept iff u < p_t(x)/p_d(x); multiplied form avoids the divide
        acc = u * jnp.maximum(pdx, _TINY) < ptx
        a = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)
        # replacement: residual norm(max(p_t - p_d, 0)) at the rejection
        # position; bonus draw from the K+1-th target distribution when
        # every draft was accepted
        pt_a = jnp.take_along_axis(pt, a[:, None, None], axis=1)[:, 0]
        pd_a = jnp.take_along_axis(pd, jnp.minimum(a, k - 1)[:, None, None],
                                   axis=1)[:, 0]
        res = jnp.maximum(pt_a - pd_a, 0.0)
        rsum = jnp.sum(res, axis=-1, keepdims=True)
        # rsum == 0 <=> p_t == p_d pointwise, where rejection has
        # probability 0 — the p_t fallback only guards the impossible draw
        res = jnp.where(rsum > 0, res / jnp.maximum(rsum, _TINY), pt_a)
        dist = jnp.where((a >= k)[:, None], pt_a, res)
        extra = jax.random.categorical(k_res, jnp.log(dist + _TINY), axis=-1)
    padded = jnp.concatenate([draft_toks, extra[:, None]], axis=1)
    out = jnp.where(steps[None, :] < a[:, None], padded, extra[:, None])
    return a, out.astype(jnp.int32), extra.astype(jnp.int32)


def emit_counts(out_tokens: jnp.ndarray, accept_len: jnp.ndarray, *,
                active: jnp.ndarray, emitted: jnp.ndarray,
                budget: jnp.ndarray, eos_id: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Truncate each slot's emitted window to its remaining budget and its
    first EOS — the variable-tokens-per-tick generalization of the engine's
    on-device termination masks.

    Returns ``(n_emit (B,), done (B,))``: inactive slots emit 0; active
    slots emit ``min(accept_len + 1, budget - emitted)`` tokens, cut at the
    first EOS inside that window (``eos_id < 0`` never matches). ``done``
    marks slots whose request finished this tick (budget reached or EOS).
    """
    b, t1 = out_tokens.shape
    steps = jnp.arange(t1)
    n = jnp.minimum(accept_len + 1, budget - emitted)          # >= 1 if active
    hit = (out_tokens == eos_id) & (steps[None, :] < n[:, None])
    first = jnp.min(jnp.where(hit, steps[None, :], t1), axis=1)
    eos_hit = first < n
    n = jnp.where(eos_hit, first + 1, n)
    n = jnp.where(active, n, 0)
    done = active & ((emitted + n >= budget) | eos_hit)
    return n, done
