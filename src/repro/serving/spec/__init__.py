"""Self-speculative serving: the 3-bit model drafts, full precision verifies.

The paper's central trade — an aggressively quantized fixed-point network is
nearly free to evaluate yet barely loses accuracy — makes the quantized
serve forms the ideal *drafters* for the full-precision weights they were
derived from. Instead of accepting the (small) accuracy delta of serving
``qp`` directly, speculative decoding turns it into a throughput multiplier
for the ``w`` form: each tick the packed-3-bit drafter proposes K tokens
through the existing fused-kernel decode path, the target model scores all
K+1 positions in ONE batched multi-token ``verify_step``, and vectorized
acceptance-rejection sampling keeps the longest prefix the target agrees
with — by construction the emitted stream follows the TARGET distribution
exactly at any temperature (token-identical to non-spec greedy at T=0).

Pieces (all pure functions of device arrays — one jitted tick composes
them, no per-draft-token host sync):

  draft.py   ``draft_chain``: K+1 sequential drafter ``decode_step`` calls
             under ``lax.scan`` (the +1 keeps the drafter's cache entry for
             its own last proposal, so an all-accepted tick never leaves the
             draft cache short), stacking state snapshots for stateful
             (hybrid) drafters.
  verify.py  ``verify_tokens``: assembles [committed token, drafts] and runs
             the target's multi-token ``verify_step`` against the live
             cache.
  accept.py  ``spec_accept``: exact acceptance-rejection sampling (greedy
             prefix match at T=0, ratio-test + residual-distribution
             resampling at T>0) and ``emit_counts``: per-slot budget/EOS
             truncation of the emitted window.

Rejected suffixes are undone by ``models.api.rollback_cache`` (length
rewind + wiped-entry zeroing + hybrid SSM-state snapshot select); the
``ssm`` family rejects spec mode loudly — its SSD state can't rewind.

``spec_decode_tick`` composes the four: it is THE tick core, shared by
``ServingEngine._spec_tick`` and the jitted ``generate(spec_k=)`` loop so
the subtle commit-length/rollback arithmetic exists exactly once.
"""
import jax
import jax.numpy as jnp

from repro.serving.spec.accept import emit_counts, spec_accept
from repro.serving.spec.draft import draft_chain
from repro.serving.spec.verify import verify_tokens

__all__ = ["draft_chain", "verify_tokens", "spec_accept", "emit_counts",
           "spec_decode_tick"]


def spec_decode_tick(mod, dmod, params, dparams, cfg, dcfg, cache, dcache,
                     pending, active, *, spec_k: int, temperature: float,
                     key, mkw, dmkw, attn_kw=None, dattn_kw=None,
                     logit_bias=None):
    """One speculative tick: draft -> verify -> accept -> rollback of BOTH
    caches. Pure function of device arrays (callers jit it, alone or inside
    a while_loop).

    ``pending`` (B, 1) is each row's sampled-but-unfed token; ``active``
    (B,) rows advance, inactive rows are frozen (their scratch-writes fully
    rewound, their pending token held). Returns ``(cache, dcache,
    accept_len (B,), out_tokens (B, spec_k+1), new_pending (B, 1),
    row_ok (B,))`` — budget/EOS window truncation (``emit_counts``) is the
    caller's, since only it knows the budget semantics.

    ``row_ok`` is the on-device health check: True iff every verify logit
    of that row is finite. A poisoned row (NaN/Inf anywhere in its target
    logits) is treated as INACTIVE for this tick — its scratch-writes are
    fully rewound, its pending token held, nothing committed — so callers
    can quarantine it from the flag alone without ever sampling from the
    corrupt distribution. ``logit_bias`` (B,) is added to the verify
    logits before acceptance; the engine threads its fault-injection
    poison vector through it (zeros in healthy operation, so the graph is
    identical either way).

    Commit arithmetic (the one copy of it): both caches advanced by
    ``spec_k+1`` writes in lockstep, and the committed stream grows by the
    pending token plus ``accept_len`` accepted drafts, so advancing rows
    rewind to ``len - (spec_k+1) + 1 + accept_len`` and frozen (inactive
    or poisoned) rows all the way back to ``len - (spec_k+1)``.
    """
    kd, ka = jax.random.split(key)
    dcache, dtraj, drafts, dlogits = draft_chain(
        dmod, dparams, dcache, pending, dcfg, spec_k=spec_k,
        temperature=temperature, key=kd, mkw=dmkw, attn_kw=dattn_kw)
    tlogits, cache, vtraj = verify_tokens(params, cache, pending, drafts,
                                          cfg, **mkw, **(attn_kw or {}))
    if logit_bias is not None:
        tlogits = tlogits + logit_bias[:, None, None]
    # health check: one cheap reduction per row, no extra output sync —
    # the flag rides the caller's existing drain
    row_ok = jnp.all(jnp.isfinite(tlogits), axis=(1, 2))
    advance = active & row_ok
    a, out, nxt = spec_accept(drafts, dlogits, tlogits,
                              temperature=temperature, key=ka)
    t1 = spec_k + 1
    rows = jnp.arange(pending.shape[0])
    commit = jnp.where(advance, cache["len"] - t1 + 1 + a, cache["len"] - t1)
    cache = mod.rollback_cache(cache, rows, commit, vtraj)
    dcache = dmod.rollback_cache(dcache, rows, commit, dtraj)
    new_pending = jnp.where(advance[:, None], nxt[:, None], pending)
    return cache, dcache, a, out, new_pending, row_ok
