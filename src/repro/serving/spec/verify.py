"""Verify phase: one batched multi-token target pass over [pending, drafts].

Thin assembly over ``models.api.verify_step`` — the causal-masked
multi-token decode entry point each family implements (``ssm`` raises).
Position ``t`` of the returned logits is the target's distribution over the
token following input ``t``, which is exactly what acceptance-rejection
needs: logits 0..K-1 judge drafts 1..K and logits K supply the bonus token
when everything is accepted.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import api as model_api

__all__ = ["verify_tokens"]


def verify_tokens(params, cache, pending: jnp.ndarray, drafts: jnp.ndarray,
                  cfg, **kw):
    """Score K drafts with one target pass.

    ``pending`` (B, 1) is the committed-but-unfed token, ``drafts`` (B, K)
    the drafter's proposals. Returns ``(target_logits (B, K+1, V),
    new_cache, trajectory)`` — the cache advances by K+1 written positions
    (rolled back to the accepted prefix afterwards).
    """
    inputs = jnp.concatenate([pending, drafts], axis=1)        # (B, K+1)
    return model_api.verify_step(params, cache, inputs, cfg, **kw)
