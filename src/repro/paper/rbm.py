"""Greedy layer-wise RBM pretraining (paper §2.1: "the network is pre-trained
with unsupervised greedy RBM learning... 50 epochs of 1-step contrastive
divergence, mini-batch 100, lr 0.1, momentum 0.9").

Layer 1 is Gaussian-visible/Bernoulli-hidden (real-valued standardized
inputs); upper layers are Bernoulli-Bernoulli on the previous layer's hidden
probabilities. CD-1 updates: dW = <v h>_data - <v' h'>_recon.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["pretrain_rbm_stack"]


@partial(jax.jit, static_argnames=("gaussian_visible",))
def _cd1_step(w, vb, hb, mw, mvb, mhb, v0, key, lr, momentum,
              gaussian_visible: bool):
    kh, kv = jax.random.split(key)
    # positive phase
    ph0 = jax.nn.sigmoid(v0 @ w + hb)
    h0 = (jax.random.uniform(kh, ph0.shape) < ph0).astype(jnp.float32)
    # negative phase (one Gibbs step)
    if gaussian_visible:
        v1 = h0 @ w.T + vb                       # mean-field real visible
    else:
        v1 = jax.nn.sigmoid(h0 @ w.T + vb)
    ph1 = jax.nn.sigmoid(v1 @ w + hb)
    n = v0.shape[0]
    # Hinton's practical-guide weight decay keeps wide RBMs out of
    # saturation (without it 1022 hiddens blow up to |pre-act|~6-9 and the
    # downstream MLP sees dead sigmoids)
    gw = (v0.T @ ph0 - v1.T @ ph1) / n - 2e-4 * w
    gvb = jnp.mean(v0 - v1, axis=0)
    ghb = jnp.mean(ph0 - ph1, axis=0)
    mw = momentum * mw + gw
    mvb = momentum * mvb + gvb
    mhb = momentum * mhb + ghb
    return (w + lr * mw, vb + lr * mvb, hb + lr * mhb, mw, mvb, mhb, ph0)


def pretrain_rbm_stack(params: dict, x_train: np.ndarray, *,
                       epochs: int = 50, batch: int = 100, lr: float = 0.1,
                       momentum: float = 0.9, seed: int = 0, log=None) -> dict:
    """Pretrain every hidden layer of the paper MLP (params from dnn.init).

    Hidden layers are fc0..fcN-1 ('head' stays at its random init — the paper
    pretrains the feature stack, the classifier is learned by backprop).
    Returns params with pretrained w/b (hidden biases) set.
    """
    names = [n for n in params if n != "head"]
    names.sort()
    key = jax.random.PRNGKey(seed + 7)
    data = jnp.asarray(x_train)
    out = {k: dict(v) for k, v in params.items()}
    for li, name in enumerate(names):
        w = out[name]["w"]
        vb = jnp.zeros((w.shape[0],), jnp.float32)
        hb = jnp.zeros((w.shape[1],), jnp.float32)
        mw, mvb, mhb = jnp.zeros_like(w), jnp.zeros_like(vb), jnp.zeros_like(hb)
        # inputs live in [0,1] (8-bit gray analogue) -> Bernoulli everywhere,
        # the Hinton/paper MNIST recipe; Gaussian-visible CD-1 at lr 0.1
        # diverges and is not what the paper ran
        gaussian = False
        n = data.shape[0]
        steps = max(n // batch, 1)
        for ep in range(epochs):
            perm = jax.random.permutation(jax.random.fold_in(key, ep * 131 + li), n)
            for s in range(steps):
                v0 = data[perm[s * batch:(s + 1) * batch]]
                key, k2 = jax.random.split(key)
                w, vb, hb, mw, mvb, mhb, _ = _cd1_step(
                    w, vb, hb, mw, mvb, mhb, v0, k2,
                    jnp.asarray(lr, jnp.float32), momentum, gaussian)
            if log and (ep + 1) % 10 == 0:
                log(f"  rbm[{name}] epoch {ep + 1}/{epochs}")
        out[name]["w"] = w
        out[name]["b"] = hb                       # hidden biases seed the MLP
        # propagate data through the trained layer for the next RBM
        data = jax.nn.sigmoid(data @ w + hb)
    return out
