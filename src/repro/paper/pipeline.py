"""The paper's experiment, end to end (§2.1):

  step 1  float training           (SGD, momentum 0.9 — paper's recipe)
  step 2  optimal uniform quantization of the weights (L2, per layer)
  step 3  retraining with fixed-point weights in the forward path (STE)

applied to the digit net (784-1022-1022-1022-10) and the phoneme net
(429-1022x4-61), with the paper's W3(hidden)/W8(output)/A8(signals) policy.

The reproduced claim: the W3A8 network lands within a fraction of a percent
of the float network (paper: digit MCR 1.08% vs 1.06%; phoneme PER 28.39% vs
27.81% — gaps of 0.02pp and 0.58pp). MNIST/TIMIT are not available in this
container, so the synthetic tasks of data.synthetic (same dims) carry the
claim; the measured quantity is the float->W3A8 *gap*.

Also validates the deployment path: export_packed -> packed inference ==
fake-quant inference (bit-exact levels), incl. through the Pallas qmatvec
kernel in interpret mode.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim as optim_lib
from repro.core import qat, quant_dense
from repro.core.precision import FLOAT, QuantPolicy
from repro.data.synthetic import ClassificationTask, digit_task, phoneme_task
from repro.models import dnn
from repro.training.losses import accuracy, softmax_xent

__all__ = ["PaperRunConfig", "run_paper_experiment", "train_mlp", "evaluate"]


@dataclasses.dataclass(frozen=True)
class PaperRunConfig:
    task: str = "digit"              # digit | phoneme
    hidden: Optional[tuple] = None   # None => paper's exact sizes
    pretrain_epochs: int = 50        # paper: 50 epochs CD-1 RBM per layer
    float_epochs: int = 100          # paper: 100
    retrain_epochs: int = 100        # paper: 100 ("same training parameters")
    batch: int = 100                 # paper: 100 (digit) / 128 (phoneme)
    lr: float = 0.1                  # paper: 0.1 (digit) / 0.05 (phoneme)
    momentum: float = 0.9            # paper: 0.9
    seed: int = 0
    act_bits: int = 8                # paper: 8-bit signals
    hidden_bits: int = 3             # paper: 3-bit hidden weights
    output_bits: int = 8             # paper: 8-bit output layer

    def resolved(self) -> Tuple[ClassificationTask, tuple, float, int]:
        if self.task == "digit":
            t = digit_task(seed=self.seed)
            hidden = self.hidden or (1022, 1022, 1022)
            return t, hidden, self.lr, self.batch
        t = phoneme_task(seed=self.seed)
        hidden = self.hidden or (1022, 1022, 1022, 1022)
        return t, hidden, 0.05 if self.lr == 0.1 else self.lr, 128


def _policy(rc: PaperRunConfig, mode: str) -> QuantPolicy:
    return QuantPolicy(mode=mode, act_bits=rc.act_bits if mode != "float" else None,
                       bits={"hidden": rc.hidden_bits, "output": rc.output_bits,
                             "embed": 8, "router": 8})


def train_mlp(params, task: ClassificationTask, *, policy: QuantPolicy,
              deltas=None, epochs: int, batch: int, lr: float,
              momentum: float, seed: int = 0, log=None) -> Tuple[dict, Dict]:
    """SGD-momentum training of the paper MLP under a policy."""
    opt = optim_lib.sgd(momentum=momentum)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        def loss_fn(p):
            logits = dnn.forward(p, x, policy=policy, deltas=deltas)
            return softmax_xent(logits, y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state2 = opt.update(grads, opt_state, params,
                                         jnp.asarray(lr, jnp.float32))
        return optim_lib.apply_updates(params, updates), opt_state2, loss

    t0 = time.time()
    losses = []
    for ep in range(epochs):
        for x, y in task.batches("train", batch, seed=seed + ep):
            params, opt_state, loss = step(params, opt_state, x, y)
        losses.append(float(loss))
        if log:
            log(f"  epoch {ep + 1}/{epochs} loss {float(loss):.4f}")
    return params, {"final_loss": losses[-1] if losses else float("nan"),
                    "train_time_s": time.time() - t0}


def evaluate(params, task: ClassificationTask, *, policy: QuantPolicy,
             deltas=None, batch: int = 500) -> float:
    """Returns miss-classification rate (MCR, %) on the test split."""
    @jax.jit
    def acc_batch(x, y):
        logits = dnn.forward(params, x, policy=policy, deltas=deltas)
        return accuracy(logits, y)

    accs = [float(acc_batch(x, y)) for x, y in task.batches("test", batch)]
    return 100.0 * (1.0 - sum(accs) / len(accs))


def run_paper_experiment(rc: PaperRunConfig, *, log=print) -> Dict:
    """Full 3-step pipeline. Returns the metrics dict for EXPERIMENTS.md."""
    task, hidden, lr, batch = rc.resolved()
    key = jax.random.PRNGKey(rc.seed)
    params0 = dnn.init(key, task.input_dim, hidden, task.num_classes)
    n_params = dnn.num_params(params0)
    log(f"[{rc.task}] net {task.input_dim}-{'-'.join(map(str, hidden))}-"
        f"{task.num_classes} ({n_params / 1e6:.2f}M params)")

    # -- step 0 (paper §2.1): greedy RBM pretraining -----------------------------
    # CD-1 lr = backprop lr / 10 (+ Hinton weight decay in rbm.py): the
    # paper's nominal 0.1 saturates wide RBMs on this synthetic task — see
    # EXPERIMENTS.md §Repro notes.
    if rc.pretrain_epochs:
        from repro.paper.rbm import pretrain_rbm_stack
        log(f"[{rc.task}] step 0: RBM pretraining ({rc.pretrain_epochs} epochs/layer)")
        params0 = pretrain_rbm_stack(params0, task.train[0],
                                     epochs=rc.pretrain_epochs, batch=batch,
                                     lr=lr * 0.1, momentum=rc.momentum,
                                     seed=rc.seed, log=log)

    # -- step 1: float training ------------------------------------------------
    log(f"[{rc.task}] step 1: float training ({rc.float_epochs} epochs)")
    fparams, fstats = train_mlp(params0, task, policy=FLOAT, epochs=rc.float_epochs,
                                batch=batch, lr=lr, momentum=rc.momentum,
                                seed=rc.seed, log=log)
    float_mcr = evaluate(fparams, task, policy=FLOAT)
    log(f"[{rc.task}] float MCR {float_mcr:.2f}%")

    # -- step 2: optimal uniform quantization ----------------------------------
    policy_q = _policy(rc, "fake")
    deltas = quant_dense.fit_deltas(fparams, policy_q)
    direct_mcr = evaluate(fparams, task, policy=policy_q, deltas=deltas)
    log(f"[{rc.task}] step 2: direct quantization (no retrain) MCR {direct_mcr:.2f}%")

    # -- step 3: retraining with quantized forward ------------------------------
    log(f"[{rc.task}] step 3: QAT retraining ({rc.retrain_epochs} epochs)")
    qparams, qstats = train_mlp(fparams, task, policy=policy_q, deltas=None,
                                epochs=rc.retrain_epochs, batch=batch, lr=lr,
                                momentum=rc.momentum, seed=rc.seed + 100, log=log)
    retrained_mcr = evaluate(qparams, task, policy=policy_q, deltas=None)
    log(f"[{rc.task}] W3A8 (retrained) MCR {retrained_mcr:.2f}%")

    # -- deployment: packed inference == fake-quant inference -------------------
    packed = quant_dense.export_packed(qparams, policy_q)
    x0, y0 = next(task.batches("test", 128))
    ref_logits = dnn.forward(qparams, x0, policy=policy_q)
    pk_logits = _packed_forward(packed, x0, rc)
    packed_err = float(jnp.max(jnp.abs(ref_logits - pk_logits)))
    # activation-quantization differences aside, levels must agree closely
    log(f"[{rc.task}] packed-vs-fakequant max |dlogit| {packed_err:.3e}")

    return {
        "task": rc.task, "params_M": n_params / 1e6,
        "float_mcr": float_mcr, "direct_quant_mcr": direct_mcr,
        "w3a8_mcr": retrained_mcr, "gap_pp": retrained_mcr - float_mcr,
        "packed_max_err": packed_err,
        "float_train_s": fstats["train_time_s"],
        "retrain_s": qstats["train_time_s"],
        "weight_bytes_float": int(n_params * 4),
        "weight_bytes_packed": _packed_bytes(packed),
    }


def _packed_forward(packed, x, rc: PaperRunConfig):
    """Inference through packed leaves (jnp unpack path; kernel validated in
    tests). Mirrors dnn.forward's layer structure."""
    n = len(packed)
    names = [f"fc{i}" for i in range(n - 1)] + ["head"]
    h = x
    for i, name in enumerate(names):
        leaf = packed[name]
        h = quant_dense.packed_apply(leaf["w"], h, use_kernel=False)
        h = h + leaf["b"]
        if i < n - 1:
            h = jax.nn.sigmoid(h)
            h = qat.fake_quant_act(h, rc.act_bits, signed=False)
    return h


def _packed_bytes(packed) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(packed):
        total += leaf.size * leaf.dtype.itemsize
    return int(total)
