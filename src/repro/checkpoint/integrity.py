"""Weight-store integrity: golden manifests + in-graph corruption probes.

The paper's deployment model keeps the packed 3-bit weight image resident
in on-chip memory for the life of the service — there is no per-batch DRAM
reload to launder soft errors out, so a flipped bit in a packed container
serves garbage *forever* unless something notices ("A Survey of FPGA-Based
Neural Network Accelerator" flags exactly this reliability gap for on-chip
deployments). This module is the noticing machinery:

  * **Golden manifest** — per-container CRC32 checksums over the protected
    leaves (packed ``qp`` words, ``q`` levels, ``delta`` scales; every
    weight leaf for float master trees), computed once at load
    (:func:`build_manifest`) and persisted with a golden copy of the
    leaves themselves (:func:`save_golden`) so a detected corruption can
    be healed by reloading just the bad container.
  * **In-graph probe** — :func:`make_probe` builds a jitted *canary
    matvec*: each protected leaf, viewed as raw words, is dotted with a
    fixed odd-multiplier vector in wrapping uint32 arithmetic
    (``fingerprint = bits @ r  (mod 2**32)``). Any single-bit flip at word
    ``j`` perturbs the sum by ``r_j * 2**b``, which is nonzero mod 2**32
    for every bit position because ``r_j`` is odd — so one cheap pass over
    the weight store (the same traffic as one decode matvec) detects any
    single-bit corruption AND localizes it to the container, with no host
    checksum scan on the hot path. The serving engine compares the probe's
    (P,) fingerprint vector against the golden one every
    ``integrity_every`` ticks.

Host-side verification (:func:`verify_manifest`) cross-checks the same
leaves against the CRC manifest — the slow, exact oracle the probe's
fingerprints are tested against, and the post-heal confirmation.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.treeutil import flatten_with_path, tree_get

__all__ = ["protected_paths", "build_manifest", "verify_manifest",
           "save_manifest", "load_manifest", "make_probe", "fingerprints",
           "save_golden", "load_golden"]

# the weight-store leaves integrity protects in a serve-form tree: packed
# container words, quantized levels, and their per-channel scales
_SERVE_LEAVES = ("qp", "q", "delta")


def _basename(path: str) -> str:
    return path.rsplit("/", 1)[-1]


def protected_paths(tree: Any) -> List[str]:
    """Tree paths of the leaves the integrity machinery covers: the packed
    level/scale arrays (``qp``/``q``/``delta``) when the tree is a serve
    form, else every array leaf (float master trees — the whole store is
    the resident image then)."""
    flat = flatten_with_path(tree)
    serve = [p for p in flat if _basename(p) in _SERVE_LEAVES]
    if serve:
        return sorted(serve)
    return sorted(p for p, v in flat.items() if hasattr(v, "dtype"))


def _crc(leaf) -> int:
    a = np.ascontiguousarray(np.asarray(leaf))
    return zlib.crc32(a.tobytes()) & 0xFFFFFFFF


def build_manifest(tree: Any,
                   paths: Optional[List[str]] = None) -> Dict[str, Dict]:
    """{path: {crc32, shape, dtype}} over the protected leaves — computed
    at load time, before anything could have corrupted the store."""
    paths = protected_paths(tree) if paths is None else paths
    out: Dict[str, Dict] = {}
    for p in paths:
        leaf = np.asarray(tree_get(tree, p))
        out[p] = {"crc32": _crc(leaf), "shape": list(leaf.shape),
                  "dtype": str(leaf.dtype)}
    return out


def verify_manifest(tree: Any, manifest: Dict[str, Dict]) -> List[str]:
    """Paths whose current bytes disagree with the manifest (crc or
    shape/dtype) — empty means the store matches its golden state. This is
    the exact host-side oracle; the serving hot path uses the in-graph
    probe and only falls back here for post-heal confirmation."""
    bad: List[str] = []
    for p, rec in manifest.items():
        leaf = np.asarray(tree_get(tree, p))
        if (list(leaf.shape) != rec["shape"]
                or str(leaf.dtype) != rec["dtype"]
                or _crc(leaf) != rec["crc32"]):
            bad.append(p)
    return sorted(bad)


def save_manifest(path: str, manifest: Dict[str, Dict]):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def load_manifest(path: str) -> Dict[str, Dict]:
    with open(path) as f:
        return json.load(f)


# --- in-graph canary probe ----------------------------------------------------

def _as_words(x: jnp.ndarray) -> jnp.ndarray:
    """Raw machine words of a leaf as a flat uint32 vector — bit-exact
    view, so the fingerprint sees every bit of the stored representation
    (a float NaN payload flip is as visible as an int level flip)."""
    if jnp.issubdtype(x.dtype, jnp.floating):
        word = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}[x.dtype.itemsize]
        x = jax.lax.bitcast_convert_type(x, word)
    return x.reshape(-1).astype(jnp.uint32)


def _fingerprint_one(x: jnp.ndarray) -> jnp.ndarray:
    v = _as_words(x)
    # Knuth multiplicative-hash weights forced odd: r_j * 2^b != 0 mod 2^32
    # for any bit b < 32, so a single flipped bit always moves the sum
    r = (jnp.arange(v.shape[0], dtype=jnp.uint32)
         * jnp.uint32(2654435761)) | jnp.uint32(1)
    return jnp.sum(v * r, dtype=jnp.uint32)


def make_probe(tree: Any, paths: Optional[List[str]] = None
               ) -> Tuple[List[str], Callable[[Any], jnp.ndarray]]:
    """(paths, probe_fn): ``probe_fn(tree) -> (len(paths),) uint32`` — the
    jittable canary pass. One fingerprint per protected container, so a
    mismatch against the golden vector localizes the corruption without
    any host-side scan."""
    paths = protected_paths(tree) if paths is None else paths

    def probe(t):
        return jnp.stack([_fingerprint_one(tree_get(t, p)) for p in paths])

    return paths, probe


def fingerprints(tree: Any, paths: Optional[List[str]] = None) -> np.ndarray:
    """One-shot host-visible fingerprints (builds and runs the probe)."""
    paths, probe = make_probe(tree, paths)
    return np.asarray(jax.jit(probe)(tree))


# --- golden store -------------------------------------------------------------

def save_golden(golden_dir: str, tree: Any,
                paths: Optional[List[str]] = None) -> Dict[str, Dict]:
    """Persist the golden copy of the protected leaves + their manifest
    under ``golden_dir`` (atomic, via the checkpoint step machinery).
    Returns the manifest. This is what self-heal reloads from: corruption
    of the resident store is repaired container-by-container without
    touching the healthy leaves."""
    from repro import checkpoint
    paths = protected_paths(tree) if paths is None else paths
    flat = {p: np.asarray(tree_get(tree, p)) for p in paths}
    manifest = build_manifest(tree, paths)
    checkpoint.save(golden_dir, 0, flat, meta={"kind": "golden"})
    save_manifest(os.path.join(golden_dir, "manifest.json"), manifest)
    return manifest


def load_golden(golden_dir: str
                ) -> Tuple[Dict[str, np.ndarray], Dict[str, Dict]]:
    """(flat {path: array}, manifest) back from :func:`save_golden`."""
    from repro import checkpoint
    tree, _ = checkpoint.restore(golden_dir, 0)
    manifest = load_manifest(os.path.join(golden_dir, "manifest.json"))
    return flatten_with_path(tree), manifest
