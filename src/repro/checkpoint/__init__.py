"""Checkpointing: flat-.npz tree snapshots with step management, keep-k GC,
async (background-thread) saves, and **elastic restore** — params saved from
one mesh can be restored onto a different mesh shape (arrays are saved
unsharded; restore re-shards via device_put with the new sharding tree),
which is the checkpoint/restart story for node failures and elastic scaling.

Format: <dir>/step_<N>/arrays.npz + meta.json. Writes go to a tmp dir and are
atomically renamed, so a killed job never leaves a half-written checkpoint
(restore scans only *complete* step dirs).

The sibling :mod:`repro.checkpoint.integrity` module applies the same
durability story to the SERVING weight store: golden per-container CRC
manifests over the packed level/scale arrays, an in-graph fingerprint
probe that detects (and localizes) bit flips in the resident image, and
the golden copy self-heal reloads corrupted containers from.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.treeutil import flatten_with_path, unflatten

__all__ = ["save", "restore", "latest_step", "all_steps", "Checkpointer"]


def _np_tree(tree) -> Dict[str, np.ndarray]:
    return {k: np.asarray(v) for k, v in flatten_with_path(tree).items()}


def save(ckpt_dir: str, step: int, tree: Any, *, meta: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _np_tree(jax.tree_util.tree_map(
        lambda x: jax.device_get(x) if hasattr(x, "device") else x, tree))
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        # extension dtypes (bfloat16, fp8) survive np.savez only as raw
        # void bytes; record the true dtypes so restore can view them back
        # instead of silently degrading the tree
        json.dump({"step": step,
                   "_dtypes": {k: str(v.dtype) for k, v in flat.items()},
                   **(meta or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:012d}"), ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None, *,
            shardings: Any = None) -> tuple:
    """Load (tree, meta). ``shardings``: optional tree of NamedSharding to
    re-shard onto a (possibly different) mesh — the elastic-restart path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:012d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    dtypes = meta.pop("_dtypes", {})
    for k, want in dtypes.items():
        if k in flat and str(flat[k].dtype) != want:
            flat[k] = flat[k].view(np.dtype(want))   # bf16 et al. round-trip
    tree = unflatten(flat)
    if shardings is not None:
        flat_s = flatten_with_path(shardings)
        flat_t = flatten_with_path(tree)
        tree = unflatten({
            k: jax.device_put(v, flat_s[k]) if k in flat_s else v
            for k, v in flat_t.items()})
    return tree, meta


class Checkpointer:
    """Async checkpointer: save() returns immediately; a background thread
    serializes (one in flight at a time — back-pressure on the next save)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any, meta: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # snapshot now

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, meta=meta, keep=self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
