"""The unified kernel dispatch (quant_dense.serve_apply / models
``matmul_mode``): kernel-path numerics match the dequant fallback and the
``effective_weight`` oracle, kernel-path decode is TOKEN-IDENTICAL to the
dequant path for every family x serve form, and — the tentpole invariant —
the jitted decode graph in 'kernel' mode contains NO dequantized full-size
weight matrix (asserted on the jaxpr; the Pallas calls carry the matmuls)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import check_no_dequant, forbidden_dequant_shapes
from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.packing import pack_matrix
from repro.core.precision import W3A8
from repro.models import api as model_api
from repro.models import get_model
from repro.serving.engine import generate

W3 = dataclasses.replace(W3A8, act_bits=None)

ARCH_FOR = {"dense": "qwen2-1.5b", "moe": "phi3.5-moe-42b-a6.6b",
            "ssm": "mamba2-2.7b", "hybrid": "zamba2-1.2b"}
PROMPT = [1, 2, 3, 4]


def _setup(family, form):
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    export = (quant_dense.export_levels if form == "q"
              else quant_dense.export_container)
    return cfg, export(params, W3), params


# --- serve_apply unit parity ------------------------------------------------------

def _leaf(form, k=48, n=40, bias=True, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.randint(ks[0], (k, n), -3, 4, jnp.int8)
    d = jnp.abs(jax.random.normal(ks[1], (n,))) * 0.1 + 0.01
    leaf = {"delta": d.reshape(1, n)}
    if form == "qp":
        leaf["qp"] = pack_matrix(q, 3)
    else:
        leaf["q"] = q
    if bias:
        leaf["b"] = jax.random.normal(ks[2], (n,)) * 0.1
    return leaf


@pytest.mark.parametrize("bias", [True, False])
@pytest.mark.parametrize("form", ["q", "qp"])
def test_serve_apply_kernel_matches_dequant_and_oracle(form, bias):
    leaf = _leaf(form, bias=bias)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 48))
    out_k = quant_dense.serve_apply(leaf, x, mode="kernel", interpret=True)
    out_d = quant_dense.serve_apply(leaf, x, mode="dequant")
    w = quant_dense.effective_weight(leaf, W3A8, "hidden", k=48)
    oracle = x @ w.astype(x.dtype)
    if bias:
        oracle = oracle + leaf["b"]
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_d),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)


def test_tied_logits_matches_dequant_readout():
    """(h * delta) @ q^T == h @ (q * delta)^T, kernel and fused paths."""
    v, d = 64, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    table = {"q": jax.random.randint(ks[0], (v, d), -127, 128, jnp.int8),
             "delta": (jnp.abs(jax.random.normal(ks[1], (d,))) * 0.01
                       + 1e-3).reshape(1, d)}
    h = jax.random.normal(ks[2], (3, 1, d))
    oracle = h @ (table["q"].astype(jnp.float32) * table["delta"]).T
    for mode in ("kernel", "dequant"):
        out = quant_dense.tied_logits(table, h, mode=mode,
                                      interpret=mode == "kernel")
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   rtol=1e-4, atol=1e-4)


def test_resolve_matmul_mode():
    assert quant_dense.resolve_matmul_mode("kernel") == "kernel"
    assert quant_dense.resolve_matmul_mode("dequant") == "dequant"
    assert quant_dense.resolve_matmul_mode("auto") in ("kernel", "dequant")
    with pytest.raises(ValueError):
        quant_dense.resolve_matmul_mode("nope")


# --- per-family token parity ------------------------------------------------------

@pytest.mark.parametrize("form", ["q", "qp"])
@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_kernel_decode_tokens_match_dequant(family, form):
    """Greedy decode through models/api.py must be token-identical between
    the Pallas kernel path (interpret mode) and the dequant fallback."""
    cfg, sp, _ = _setup(family, form)
    prompts = jnp.asarray([PROMPT], jnp.int32)
    out_k = generate(sp, prompts, cfg, policy=W3, max_new_tokens=3,
                     dtype=jnp.float32, matmul_mode="kernel")
    out_d = generate(sp, prompts, cfg, policy=W3, max_new_tokens=3,
                     dtype=jnp.float32, matmul_mode="dequant")
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_d),
                                  err_msg=f"{family}/{form}")


# --- the tentpole invariant: no dequantized weight in the decode graph ------------
# (the shape-forbidding and jaxpr-walking live in repro.analysis now — the
# shared pass keeps this test's exact strictness: a forbidden-shape hit OR
# a missing pallas_call is a violation)

@pytest.mark.parametrize("form", ["q", "qp"])
@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_kernel_mode_decode_graph_has_no_dequantized_weight(family, form):
    cfg, sp, float_params = _setup(family, form)
    forbidden = forbidden_dequant_shapes(float_params, W3)
    cache = model_api.init_cache(cfg, 2, 16, jnp.float32, per_slot_len=True)
    toks = jnp.zeros((2, 1), jnp.int32)

    def run(mode):
        fn = lambda c, t: model_api.decode_step(
            sp, c, t, cfg, policy=W3, dtype=jnp.float32, matmul_mode=mode)
        return jax.make_jaxpr(fn)(cache, toks)

    viols = check_no_dequant(run("kernel"), forbidden, require_pallas=True)
    assert not viols, (f"{family}/{form}: "
                       + "; ".join(str(v) for v in viols))
    # detector sanity: the dequant fallback DOES build per-layer (K, N)
    # float operands (levels cast to the activation dtype), so the same
    # check must trip there — otherwise the assertion above is vacuous
    assert check_no_dequant(run("dequant"), forbidden,
                            require_pallas=False), \
        "shape detector lost its reference signal"
