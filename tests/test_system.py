"""End-to-end system behaviour: the full paper workflow on a miniature LM —
QAT train -> export packed -> serve — plus elastic checkpoint re-shard."""
import tempfile

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import TrainConfig, get_config, reduced
from repro.core import quant_dense
from repro.core.precision import W3A8
from repro.data.pipeline import HostLoader
from repro.data.synthetic import lm_batch
from repro.models import get_model
from repro.serving.engine import generate
from repro.training.loop import Trainer, make_train_step


def test_full_quantized_lm_workflow():
    """Train a tiny LM with the paper's W3A8 QAT, deploy packed, generate."""
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=32, vocab=64)
    mod = get_model(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(learning_rate=2e-3, total_steps=30, warmup_steps=3)
    step, init_state = make_train_step(cfg, tcfg, W3A8, dtype=jnp.float32)
    step = jax.jit(step)
    loader = HostLoader(lambda seed, s: lm_batch(
        jnp.asarray(seed), jnp.asarray(s), batch=8, seq=16, vocab=64))

    with tempfile.TemporaryDirectory() as td:
        ck = ckpt_lib.Checkpointer(td, keep=2)
        tr = Trainer(step, init_state(params), checkpointer=ck,
                     ckpt_every=10, log_every=10)
        state = tr.run(loader, 30)
        assert tr.history[-1]["loss"] < tr.history[0]["loss"]

        # deploy: packed serve params (the paper's BRAM image)
        serve = quant_dense.export_container(state["params"], W3A8)
        prompts = jnp.zeros((2, 4), jnp.int32)
        out = generate(serve, prompts, cfg, policy=W3A8, max_new_tokens=6,
                       dtype=jnp.float32)
        assert out.shape == (2, 10)
        assert not bool(jnp.any(jnp.isnan(out)))

        # elastic restore: same checkpoint, fresh process/mesh story
        tree, meta = ckpt_lib.restore(td)
        assert meta["step"] in (10, 20, 30)
        flat = jax.flatten_util.ravel_pytree(tree["params"])[0]
        assert np.all(np.isfinite(np.asarray(flat, np.float32)))
