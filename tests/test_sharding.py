"""Sharding rules: divisibility guards, spec validity on the production mesh
shapes (pure spec-level checks — no 512-device init in the test process; the
real lowering proof lives in the dry-run)."""
import pytest

from repro.configs import ARCH_IDS, LM_SHAPES, get_config
from repro.core.treeutil import flatten_with_path
from repro.distributed import sharding as shd
from repro.launch.steps import input_specs, _params_template, _state_template
from repro.configs.base import TrainConfig


class FakeMesh:
    """Shape-only stand-in for the 16x16 / 2x16x16 production meshes."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


SINGLE = FakeMesh(data=16, model=16)
MULTI = FakeMesh(pod=2, data=16, model=16)


def _check_divisibility(spec_tree, shape_tree, mesh):
    flat_s = flatten_with_path(spec_tree)
    flat_t = flatten_with_path(shape_tree)
    for path, spec in flat_s.items():
        leaf = flat_t[path]
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for dim, ax in zip(leaf.shape, parts):
            if ax is None:
                continue
            assert dim % shd.axis_size(mesh, ax) == 0, \
                f"{path}: {leaf.shape} not divisible by {ax}"


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    params = _params_template(cfg, "w3", "train")
    specs = shd.param_specs(cfg, params, mesh, fsdp=True)
    _check_divisibility(specs, params, mesh)


@pytest.mark.parametrize("arch", ["qwen3-32b", "mixtral-8x22b", "mamba2-2.7b",
                                  "zamba2-1.2b"])
def test_serve_specs_divisible(arch):
    cfg = get_config(arch)
    for kind in ("prefill", "decode"):
        params = _params_template(cfg, "w3", kind)
        specs = shd.param_specs(cfg, params, SINGLE)
        _check_divisibility(specs, params, SINGLE)


def test_gqa_kv_replicated_when_not_divisible():
    cfg = get_config("qwen3-32b")            # kv=8 < model=16
    params = _params_template(cfg, "float", "train")
    specs = shd.param_specs(cfg, params, SINGLE)
    wk = flatten_with_path(specs)["layers/attn/wk/w"]
    assert all(a != "model" for a in wk)     # replicated over model
    wq = flatten_with_path(specs)["layers/attn/wq/w"]
    assert "model" in tuple(wq)


def test_mha_kv_sharded_when_divisible():
    cfg = get_config("stablelm-3b")          # kv=32 % 16 == 0
    params = _params_template(cfg, "float", "train")
    specs = shd.param_specs(cfg, params, SINGLE)
    wk = flatten_with_path(specs)["layers/attn/wk/w"]
    assert "model" in tuple(wk)


def test_moe_expert_parallel_vs_tensor_parallel():
    # phi3.5: 16 experts % 16 == 0 -> EP on the expert dim
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    params = _params_template(cfg, "float", "train")
    up = flatten_with_path(shd.param_specs(cfg, params, SINGLE))["layers/moe/up/w"]
    assert tuple(up)[1] == "model"           # (L, E, d, f): E sharded
    # mixtral: 8 experts (not divisible) -> TP inside experts
    cfg = get_config("mixtral-8x22b")
    params = _params_template(cfg, "float", "train")
    up = flatten_with_path(shd.param_specs(cfg, params, SINGLE))["layers/moe/up/w"]
    assert tuple(up)[1] is None and "model" in tuple(up)


def test_fsdp_adds_data_axis():
    cfg = get_config("qwen3-32b")
    params = _params_template(cfg, "float", "train")
    up_nofsdp = flatten_with_path(
        shd.param_specs(cfg, params, SINGLE, fsdp=False))["layers/mlp/up/w"]
    up_fsdp = flatten_with_path(
        shd.param_specs(cfg, params, SINGLE, fsdp=True))["layers/mlp/up/w"]
    assert "data" not in tuple(up_nofsdp)
    assert "data" in tuple(up_fsdp) and "model" in tuple(up_fsdp)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        specs = input_specs(cfg, s)
        assert "tokens" in specs
        assert specs["tokens"].shape[0] == s.global_batch
        if s.kind == "decode":
            assert specs["tokens"].shape == (s.global_batch, 1)
        if cfg.frontend and s.kind != "decode":
            assert "frontend_embeds" in specs


def test_state_specs_cover_optimizer():
    cfg = get_config("qwen2-1.5b")
    st = _state_template(cfg, TrainConfig(), "w3")
    specs = shd.state_specs(cfg, st, SINGLE, fsdp=True)
    assert "opt" in specs and "m" in specs["opt"]
    _check_divisibility(specs["params"], st["params"], SINGLE)
    _check_divisibility(specs["opt"]["m"], st["opt"]["m"], SINGLE)


def test_constrain_noop_outside_context():
    import jax.numpy as jnp
    from repro.distributed.context import constrain
    x = jnp.ones((4, 4))
    assert constrain(x, "act") is x
