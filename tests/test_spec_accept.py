"""Acceptance-rejection sampling (serving/spec/accept.py): the speculative
sampling lemma — emitted tokens follow the TARGET distribution exactly —
checked empirically at temperature > 0, plus the deterministic greedy
(T=0) prefix-match semantics and the budget/EOS window truncation."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hypothesis_stub import given, settings, st

from repro.serving.spec import emit_counts, spec_accept


def _greedy_case():
    """Hand-built (B=3, K=3, V=8) case with known accept lengths."""
    v = 8
    t_hat = np.array([[1, 2, 3, 4],       # target argmax per position
                      [5, 6, 7, 0],
                      [2, 2, 2, 2]])
    target_logits = np.zeros((3, 4, v), np.float32)
    for b in range(3):
        for i in range(4):
            target_logits[b, i, t_hat[b, i]] = 5.0
    drafts = np.array([[1, 2, 3],         # all match -> a=3, bonus 4
                       [5, 9, 7],         # mismatch at i=1 -> a=1, emits 6
                       [3, 2, 2]])        # mismatch at i=0 -> a=0, emits 2
    return jnp.asarray(drafts), jnp.asarray(target_logits)


def test_greedy_accept_prefix_semantics():
    drafts, tlogits = _greedy_case()
    dlogits = jnp.zeros((3, 3, 8), jnp.float32)   # unused at T=0
    a, out, nxt = spec_accept(drafts, dlogits, tlogits, temperature=0.0,
                              key=jax.random.PRNGKey(0))
    assert list(np.asarray(a)) == [3, 1, 0]
    out = np.asarray(out)
    # emitted windows: accepted drafts + the target's correction/bonus
    assert list(out[0, :4]) == [1, 2, 3, 4]
    assert list(out[1, :2]) == [5, 6]
    assert list(out[2, :1]) == [2]
    assert list(np.asarray(nxt)) == [4, 6, 2]     # next tick's pending token


def test_greedy_equals_sequential_greedy_stream():
    """The committed window [drafts[:a], correction] is exactly what
    sequential argmax decoding over the same logits would emit."""
    drafts, tlogits = _greedy_case()
    dlogits = jnp.zeros((3, 3, 8), jnp.float32)
    a, out, _ = spec_accept(drafts, dlogits, tlogits, temperature=0.0,
                            key=jax.random.PRNGKey(0))
    t_hat = np.asarray(jnp.argmax(tlogits, -1))
    for b in range(3):
        n = int(a[b]) + 1
        # sequential greedy: token i is target argmax after consuming the
        # previous target tokens — within the accepted prefix the draft IS
        # that argmax, so the streams coincide position by position
        assert list(np.asarray(out)[b, :n]) == list(t_hat[b, :n])


def test_selfdraft_always_accepts_at_any_temperature():
    """draft logits == target logits => acceptance probability 1 (the
    residual-distribution branch must not fire on the p_t == p_d case)."""
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (2, 5, 16))
    drafts = jax.random.randint(jax.random.PRNGKey(4), (2, 4), 0, 16)
    for temp in (0.7, 1.0, 2.5):
        a, _, _ = spec_accept(drafts, logits[:, :4], logits,
                              temperature=temp, key=jax.random.PRNGKey(5))
        # at T>0 drafts came from the draft distribution; here they are
        # arbitrary tokens, but the RATIO p_t/p_d == 1 regardless
        assert list(np.asarray(a)) == [4, 4]


def test_emitted_matches_target_distribution():
    """Speculative sampling lemma, empirically: the FIRST emitted token
    (accepted draft or residual resample) is distributed as the target's
    softmax — not the drafter's — for a deliberately mismatched drafter."""
    v, temp, n = 6, 0.8, 8000
    kt, kd, kx, ks = jax.random.split(jax.random.PRNGKey(7), 4)
    tlogits = jax.random.normal(kt, (1, 2, v)) * 1.5
    dlogits = jax.random.normal(kd, (1, 1, v)) * 1.5      # mismatched draft
    p_d = jax.nn.softmax(dlogits[0, 0] / temp)
    p_t = np.asarray(jax.nn.softmax(tlogits[0, 0] / temp))

    def one(key):
        k_draft, k_acc = jax.random.split(key)
        # the draft token must come from the DRAFT distribution — that's
        # the lemma's hypothesis
        x = jax.random.categorical(k_draft, dlogits[0, 0] / temp)
        _, out, _ = spec_accept(x[None, None], dlogits, tlogits,
                                temperature=temp, key=k_acc)
        return out[0, 0]

    toks = np.asarray(jax.vmap(one)(jax.random.split(ks, n)))
    emp = np.bincount(toks, minlength=v) / n
    tv = 0.5 * np.abs(emp - p_t).sum()
    # sanity: the drafter alone would NOT pass this gate
    tv_draft = 0.5 * np.abs(np.asarray(p_d) - p_t).sum()
    assert tv < 0.05, (tv, emp, p_t)
    assert tv_draft > 0.15, "degenerate case: drafter too close to target"


def test_emit_counts_budget_and_eos():
    out = jnp.asarray([[10, 11, 12, 13],      # budget cuts at 2
                       [10, 99, 12, 13],      # EOS (99) at index 1
                       [10, 11, 12, 13],      # inactive -> 0
                       [10, 11, 12, 99]])     # EOS beyond window: no hit
    a = jnp.asarray([3, 3, 3, 1])
    active = jnp.asarray([True, True, False, True])
    emitted = jnp.asarray([5, 1, 1, 1])
    budget = jnp.asarray([7, 16, 16, 16])
    n, done = emit_counts(out, a, active=active, emitted=emitted,
                          budget=budget, eos_id=99)
    assert list(np.asarray(n)) == [2, 2, 0, 2]
    assert list(np.asarray(done)) == [True, True, False, False]


def test_emit_counts_no_eos_sentinel():
    """eos_id=-1 (engine's 'no EOS' sentinel) never truncates."""
    out = jnp.asarray([[3, 4, 5]])
    n, done = emit_counts(out, jnp.asarray([2]),
                          active=jnp.asarray([True]),
                          emitted=jnp.asarray([1]), budget=jnp.asarray([99]),
                          eos_id=-1)
    assert int(n[0]) == 3 and not bool(done[0])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 6), st.integers(2, 9))
def test_accept_invariants_random(seed, k, v):
    """For arbitrary logits: a in [0, K]; the emitted window starts with
    exactly the a accepted drafts; next_pending is the window's last
    emitted token (at T=0 AND T>0)."""
    kt, kd, kx, ka = jax.random.split(jax.random.PRNGKey(seed), 4)
    tlogits = jax.random.normal(kt, (2, k + 1, v))
    dlogits = jax.random.normal(kd, (2, k, v))
    drafts = jax.random.randint(kx, (2, k), 0, v)
    for temp in (0.0, 0.9):
        a, out, nxt = spec_accept(drafts, dlogits, tlogits,
                                  temperature=temp, key=ka)
        a, out, nxt = np.asarray(a), np.asarray(out), np.asarray(nxt)
        for b in range(2):
            assert 0 <= a[b] <= k
            assert list(out[b, :a[b]]) == list(np.asarray(drafts)[b, :a[b]])
            assert out[b, a[b]] == nxt[b]
