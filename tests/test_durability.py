"""Crash durability: snapshot/restore parity (dense + hybrid x weight
forms x spec), kill-at-arbitrary-tick recovery from latest snapshot +
write-ahead journal tail (zero accepted requests lost, token-identical at
T=0), journal-only replay onto a fresh engine, torn-tail tolerance,
deterministic resume at temperature > 0 (the sampling RNG key is explicit
serialized state), and loud snapshot/engine compatibility checks.

Weight-only quantization (``act_bits=None``) for the parity assertions —
re-admission after recovery enters batched prefill at a grown length, so
exactness is a weight-only property (the same caveat as preemption and
bucketed admission; see the engine docstring).
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.serving.durability import Journal
from repro.serving.engine import ServingEngine
from repro.serving.resilience import FaultPlan, InjectedCrash

W3 = dataclasses.replace(W3A8, act_bits=None)


@pytest.fixture(scope="module", autouse=True)
def _drop_compile_caches():
    yield
    jax.clear_caches()


ARCH_FOR = {"dense": "qwen2-1.5b", "hybrid": "zamba2-1.2b"}

PROMPTS = [[1, 2, 3], [7, 8, 9, 10, 11], [20, 21, 22, 23], [30, 31],
           [40, 41, 42, 43, 44, 45], [50, 51, 52]]
MAX_NEW = [7, 5, 9, 6, 8, 4]


def _setup(family="dense", form="qp"):
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    if form == "w":
        return cfg, params, FLOAT
    export = {"q": quant_dense.export_levels,
              "qp": quant_dense.export_container}[form]
    return cfg, export(params, W3), W3


def _engine(params, cfg, policy, **kw):
    kw.setdefault("slots", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("dtype", jnp.float32)
    return ServingEngine(params, cfg, policy=policy, **kw)


def _submit_all(eng):
    for p, m in zip(PROMPTS, MAX_NEW):
        eng.submit(list(p), max_new=m)


def _outputs(done):
    return {r.uid: (tuple(r.prompt), tuple(r.out)) for r in done}


def _reference(params, cfg, policy, **kw):
    eng = _engine(params, cfg, policy, **kw)
    _submit_all(eng)
    return _outputs(eng.run_all(max_ticks=400))


# --- snapshot / restore parity ----------------------------------------------

@pytest.mark.parametrize("family", ["dense", "hybrid"])
@pytest.mark.parametrize("form", ["w", "qp"])
@pytest.mark.parametrize("spec_k", [0, 2])
def test_snapshot_restore_token_identical(tmp_path, family, form, spec_k):
    """A fresh engine restored from a mid-run snapshot continues
    token-identically at T=0 — across both families, float and packed
    serve forms, plain and speculative ticks."""
    cfg, params, policy = _setup(family, form)
    kw = dict(spec_k=spec_k)
    ref = _reference(params, cfg, policy, **kw)

    eng = _engine(params, cfg, policy, **kw)
    _submit_all(eng)
    for _ in range(4):
        eng.step()
    path = eng.snapshot(str(tmp_path / "snaps"))
    assert os.path.isdir(path)
    mid_done = _outputs(eng.drain())          # finished before the snapshot?
    a = _outputs(eng.run_all(max_ticks=400))

    fresh = _engine(params, cfg, policy, **kw)
    fresh.restore(str(tmp_path / "snaps"))
    assert fresh.decode_calls == 4
    b = _outputs(fresh.run_all(max_ticks=400))

    merged_a = {**mid_done, **a}
    merged_b = {**mid_done, **b}              # snapshot kept undrained work
    assert merged_b == merged_a == ref


def test_restore_midstream_state(tmp_path):
    """The snapshot captures in-flight requests mid-stream: the restored
    engine resumes them from their committed prefix (not from scratch) and
    the remaining budgets/tick bounds carry over."""
    cfg, params, policy = _setup()
    eng = _engine(params, cfg, policy, snapshot_dir=str(tmp_path / "s"))
    _submit_all(eng)
    for _ in range(5):
        eng.step()
    eng.snapshot()
    resident = [r for r in eng._slot_req if r is not None]
    assert resident, "expected in-flight requests at the snapshot"

    fresh = _engine(params, cfg, policy)
    fresh.restore(str(tmp_path / "s"))
    rest = {r.uid: list(r.out) for r in fresh._slot_req if r is not None}
    assert rest == {r.uid: list(r.out) for r in resident}
    assert fresh._ticks_left == eng._ticks_left
    assert fresh._slot_ticks == eng._slot_ticks
    assert fresh._uid == eng._uid


def test_snapshot_compat_checked_loudly(tmp_path):
    """Restoring onto a mismatched engine (different slot count, max_len,
    temperature) raises a ValueError naming the field instead of serving
    from inconsistent state."""
    cfg, params, policy = _setup()
    eng = _engine(params, cfg, policy)
    _submit_all(eng)
    eng.step()
    eng.snapshot(str(tmp_path / "s"))
    for bad_kw, field in ((dict(slots=4), "slots"),
                          (dict(max_len=32), "max_len"),
                          (dict(temperature=0.5), "temperature")):
        other = _engine(params, cfg, policy, **bad_kw)
        with pytest.raises(ValueError, match=field):
            other.restore(str(tmp_path / "s"))


# --- crash + recovery ---------------------------------------------------------

@pytest.mark.parametrize("crash_at", [1, 4, 9])
def test_crash_recovery_loses_nothing(tmp_path, crash_at):
    """Kill the engine at an arbitrary tick; recover a FRESH engine from
    the latest snapshot + journal tail. Every accepted request appears in
    the union of pre-crash drains and the recovered run, token-identical
    to an uncrashed run at T=0 — zero accepted-token loss."""
    cfg, params, policy = _setup()
    ref = _reference(params, cfg, policy)

    snaps, jpath = str(tmp_path / "snaps"), str(tmp_path / "wal.jsonl")
    eng = _engine(params, cfg, policy, snapshot_dir=snaps, snapshot_every=3,
                  journal=jpath, fault_plan=FaultPlan(crash_at_tick=crash_at))
    _submit_all(eng)
    delivered = {}
    with pytest.raises(InjectedCrash):
        while eng.queue or eng._occupied():
            eng.step()
            delivered.update(_outputs(eng.drain()))

    fresh = _engine(params, cfg, policy, snapshot_dir=snaps, journal=jpath)
    stats = fresh.recover()
    assert stats["replayed_events"] >= 0
    recovered = _outputs(fresh.run_all(max_ticks=400))

    merged = {**delivered, **recovered}
    assert set(merged) == set(ref), "an accepted request was lost"
    assert merged == ref, "recovered output differs from the uncrashed run"
    # anything delivered both before the crash and after recovery must
    # agree (at-least-once, never divergent)
    for uid in set(delivered) & set(recovered):
        assert delivered[uid] == recovered[uid]


def test_journal_only_replay(tmp_path):
    """With no snapshot at all, recovery replays the journal from the
    start: every accepted submit is resubmitted (uid preserved) onto the
    fresh engine and completes identically."""
    cfg, params, policy = _setup()
    ref = _reference(params, cfg, policy)
    jpath = str(tmp_path / "wal.jsonl")
    eng = _engine(params, cfg, policy, journal=jpath,
                  fault_plan=FaultPlan(crash_at_tick=2))
    _submit_all(eng)
    with pytest.raises(InjectedCrash):
        eng.run_all(max_ticks=400)

    fresh = _engine(params, cfg, policy, journal=jpath)
    stats = fresh.recover()
    assert stats["restored_step"] is None
    assert stats["resubmitted"] == len(PROMPTS)
    assert fresh._uid == len(PROMPTS)         # uid counter past replayed uids
    assert _outputs(fresh.run_all(max_ticks=400)) == ref


def test_replay_keeps_terminal_requests_dead(tmp_path):
    """Requests the dead engine had already shed stay dead across
    recovery — their terminal outcome was reported once; replay must not
    resurrect them."""
    cfg, params, policy = _setup()
    jpath = str(tmp_path / "wal.jsonl")
    eng = _engine(params, cfg, policy, journal=jpath, queue_limit=2,
                  shed_policy="drop_oldest",
                  fault_plan=FaultPlan(crash_at_tick=1))
    for p, m in zip(PROMPTS, MAX_NEW):       # queue_limit 2 sheds the oldest
        eng.submit(list(p), max_new=m)
    shed_uids = {r.uid for r in eng._finished if r.status == "shed"}
    assert shed_uids
    with pytest.raises(InjectedCrash):
        eng.run_all(max_ticks=400)

    fresh = _engine(params, cfg, policy, journal=jpath)
    fresh.recover()
    replayed = {r.uid for r in fresh.queue}
    assert not (replayed & shed_uids)
    assert fresh.queue                        # the survivors DID come back


def test_journal_torn_tail_tolerated(tmp_path):
    """A crash mid-append leaves a torn final line; Journal.read drops it
    and recovery proceeds on the intact prefix."""
    jpath = str(tmp_path / "wal.jsonl")
    j = Journal(jpath)
    j.append({"e": "submit", "uid": 1, "prompt": [1, 2], "max_new": 4,
              "deadline_at": None})
    j.close()
    with open(jpath, "a") as f:
        f.write('{"e": "submit", "uid": 2, "prom')   # torn write
    events = Journal.read(jpath)
    assert [e["uid"] for e in events] == [1]

    cfg, params, policy = _setup()
    fresh = _engine(params, cfg, policy)
    stats = fresh.recover(journal=jpath)
    assert stats["resubmitted"] == 1
    done = fresh.run_all(max_ticks=100)
    assert [r.uid for r in done] == [1] and done[0].status == "ok"


def test_periodic_snapshots_and_counters(tmp_path):
    """snapshot_every lands snapshots on tick boundaries with keep-k GC;
    the durability counters ride the watchdog diagnostics."""
    from repro import checkpoint
    cfg, params, policy = _setup()
    snaps = str(tmp_path / "snaps")
    eng = _engine(params, cfg, policy, snapshot_dir=snaps, snapshot_every=2,
                  journal=str(tmp_path / "wal.jsonl"))
    _submit_all(eng)
    eng.run_all(max_ticks=400)
    assert eng.snapshots_written >= 3
    assert checkpoint.latest_step(snaps) is not None
    assert len(checkpoint.all_steps(snaps)) <= 3          # keep-k GC
    assert eng.journal_events > 0
    d = eng._diagnostics()
    for k in ("snapshots_written", "journal_events", "replayed_events",
              "integrity_probes", "heal_count"):
        assert k in d
    # the journal is a valid event stream with snapshot markers
    events = Journal.read(str(tmp_path / "wal.jsonl"))
    kinds = {e["e"] for e in events}
    assert {"submit", "admit", "commit", "finish", "snapshot"} <= kinds


# --- deterministic resume (explicit RNG state) --------------------------------

def test_restore_is_reproducible_at_temperature(tmp_path):
    """The sampling key is explicit serialized state: two fresh engines
    restored from the same mid-run snapshot produce IDENTICAL streams even
    at temperature > 0 — and identical to the donor engine continuing."""
    cfg, params, policy = _setup()
    kw = dict(temperature=0.8, seed=7)
    eng = _engine(params, cfg, policy, **kw)
    _submit_all(eng)
    for _ in range(4):
        eng.step()
    eng.snapshot(str(tmp_path / "s"))
    mid = _outputs(eng.drain())
    donor = {**mid, **_outputs(eng.run_all(max_ticks=400))}

    restored = []
    for _ in range(2):
        fresh = _engine(params, cfg, policy, **kw)
        fresh.restore(str(tmp_path / "s"))
        restored.append({**mid, **_outputs(fresh.run_all(max_ticks=400))})
    assert restored[0] == restored[1] == donor


def test_submit_is_write_ahead(tmp_path):
    """The journal line for a submit is durable BEFORE the queue sees the
    request — a crash immediately after submit() can always replay it."""
    cfg, params, policy = _setup()
    jpath = str(tmp_path / "wal.jsonl")
    eng = _engine(params, cfg, policy, journal=jpath)
    eng.submit([1, 2, 3], max_new=4, deadline_ticks=50)
    with open(jpath) as f:
        ev = json.loads(f.readline())
    assert ev["e"] == "submit" and ev["uid"] == 1
    assert ev["prompt"] == [1, 2, 3] and ev["max_new"] == 4
    assert ev["deadline_at"] == 50
