"""Pipeline parallelism == sequential stage application.

Runs in a SUBPROCESS with forced host devices so the main pytest process
keeps the mandated single-device view (dryrun.py is the only in-repo place
allowed to set XLA_FLAGS globally)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply
from repro.launch.mesh import compat_make_mesh

mesh = compat_make_mesh((4,), ("stage",))
key = jax.random.PRNGKey(0)
S, M, B, D = 4, 6, 2, 8
ws = jax.random.normal(key, (S, D, D)) * 0.3
bs = jax.random.normal(jax.random.PRNGKey(1), (S, D)) * 0.1
params = {"w": ws, "b": bs}
x = jax.random.normal(jax.random.PRNGKey(2), (M, B, D))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

out = pipeline_apply(stage_fn, params, x, mesh)

ref = x
for s in range(S):
    ref = jnp.tanh(ref @ ws[s] + bs[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

# differentiability: grad through the pipeline matches sequential grad
def loss_pp(ws_):
    o = pipeline_apply(stage_fn, {"w": ws_, "b": bs}, x, mesh)
    return jnp.sum(o ** 2)

def loss_seq(ws_):
    h = x
    for s in range(S):
        h = jnp.tanh(h @ ws_[s] + bs[s])
    return jnp.sum(h ** 2)

g_pp = jax.grad(loss_pp)(ws)
g_seq = jax.grad(loss_seq)(ws)
np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_seq),
                           atol=1e-4, rtol=1e-4)
print("PIPELINE_OK")
"""


def test_pipeline_matches_sequential_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, cwd=".", timeout=300)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
