"""Mamba2 SSD: chunked algorithm vs naive recurrence + decode continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import _ssd_chunked

B, L, H, P, G, N = 2, 37, 4, 8, 2, 6


def _inputs(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, L, H, P))
    bm = jax.random.normal(ks[1], (B, L, G, N)) * 0.5
    cm = jax.random.normal(ks[2], (B, L, G, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, L, H)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, H))
    return x, bm, cm, dt, a_log


def _naive(x, bm, cm, dt, a_log):
    a = -jnp.exp(a_log)
    rep = H // G
    bh = jnp.repeat(bm, rep, axis=2)
    ch = jnp.repeat(cm, rep, axis=2)
    s = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        decay = jnp.exp(dt[:, t] * a)
        s = s * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], bh[:, t], x[:, t])
        ys.append(jnp.einsum("bhn,bhpn->bhp", ch[:, t], s))
    return jnp.stack(ys, 1), s


@pytest.mark.parametrize("chunk", [4, 8, 16, 37, 64])
def test_ssd_chunked_matches_recurrence(chunk):
    x, bm, cm, dt, a_log = _inputs()
    y_ref, s_ref = _naive(x, bm, cm, dt, a_log)
    y, s = _ssd_chunked(x, bm, cm, dt, a_log, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_final_state_supports_continuation():
    """State after seq[0:k] + recurrence over seq[k:] == full sequence."""
    x, bm, cm, dt, a_log = _inputs(1)
    k = 20
    _, s_full = _ssd_chunked(x, bm, cm, dt, a_log, 8)
    _, s_half = _ssd_chunked(x[:, :k], bm[:, :k], cm[:, :k], dt[:, :k],
                             a_log, 8)
    a = -jnp.exp(a_log)
    rep = H // G
    s = s_half
    for t in range(k, L):
        bh = jnp.repeat(bm[:, t], rep, axis=1)
        decay = jnp.exp(dt[:, t] * a)
        s = s * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], bh, x[:, t])
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)
