"""True positives for every repro.analysis contract pass: each check must
DEMONSTRABLY fire on a deliberately-broken graph with an actionable message
naming the offense — plus a registry/sweep smoke test and the retrace-budget
report. The carry-dtype test reintroduces the PR 5 ``mamba2.block_decode``
bf16 conv-state drift via monkeypatch and proves the pass flags it."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis import (Violation, check_carry_fixed_point,
                            check_donation, check_no_dequant,
                            check_no_host_callback,
                            check_no_quadratic_scores, check_vmem_budget,
                            forbidden_dequant_shapes, lint_combo,
                            retrace_report)
from repro.analysis.contracts import W3
from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.packing import pack_matrix
from repro.core.precision import FLOAT
from repro.models import get_model, mamba2
from repro.serving.engine import ServingEngine

SDS = jax.ShapeDtypeStruct


# --- pass 1: no_dequant ------------------------------------------------------------

def _serve_leaf(k=48, n=40):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    q = jax.random.randint(ks[0], (k, n), -3, 4, jnp.int8)
    d = jnp.abs(jax.random.normal(ks[1], (n,))) * 0.1 + 0.01
    return {"qp": pack_matrix(q, 3), "delta": d.reshape(1, n)}


def test_no_dequant_fires_on_dequant_matmul():
    leaf = _serve_leaf()
    x = SDS((8, 48), jnp.float32)
    bad = jax.make_jaxpr(
        lambda xx: quant_dense.serve_apply(leaf, xx, mode="dequant"))(x)
    viols = check_no_dequant(bad, {(48, 40)}, require_pallas=False)
    assert viols, "dequant matmul must trip the pass"
    v = viols[0]
    assert v.check == "no_dequant" and "(48, 40)" in v.message
    assert v.eqn, "violation must name the offending eqn"
    # and the kernel path is clean (incl. the pallas_call requirement)
    good = jax.make_jaxpr(
        lambda xx: quant_dense.serve_apply(leaf, xx, mode="kernel",
                                           interpret=True))(x)
    assert not check_no_dequant(good, {(48, 40)}, require_pallas=True)


def test_no_dequant_fires_on_missing_pallas():
    """Kernel mode that silently fell back (no pallas_call anywhere) is
    itself a violation under require_pallas."""
    jx = jax.make_jaxpr(lambda a: a @ a)(SDS((8, 8), jnp.float32))
    viols = check_no_dequant(jx, set(), require_pallas=True)
    assert len(viols) == 1 and "no pallas_call" in viols[0].message


# --- pass 2: no_quadratic_scores ---------------------------------------------------

def test_no_quadratic_scores_fires_on_einsum_prefill():
    t = s = 48

    def einsum_attn(q, k, v):
        scores = jnp.einsum("btd,bsd->bts", q, k) * (q.shape[-1] ** -0.5)
        return jnp.einsum("bts,bsd->btd", jax.nn.softmax(scores), v)

    args = [SDS((2, t, 16), jnp.float32)] * 3
    viols = check_no_quadratic_scores(jax.make_jaxpr(einsum_attn)(*args),
                                      t, s)
    assert viols and all(v.check == "no_quadratic_scores" for v in viols)
    assert any(f"(T={t}, S={s})" in v.message for v in viols)
    assert any("dot_general" in v.eqn or "softmax" in v.eqn
               or "exp" in v.eqn for v in viols)
    # min_rank filters coarse-point shape collisions
    assert not check_no_quadratic_scores(jax.make_jaxpr(einsum_attn)(*args),
                                         t, s, min_rank=4)


# --- pass 3: no_host_callback ------------------------------------------------------

def test_no_host_callback_fires_on_debug_callback():
    def tick(c):
        jax.debug.print("tok {}", c.sum())
        return c + 1

    viols = check_no_host_callback(jax.make_jaxpr(tick)(SDS((4,),
                                                          jnp.float32)))
    assert viols and "debug_callback" in viols[0].message
    assert "sync" in viols[0].message
    assert not check_no_host_callback(
        jax.make_jaxpr(lambda c: c + 1)(SDS((4,), jnp.float32)))


# --- pass 4: carry_dtype (the PR 5 bug class) --------------------------------------

def test_carry_fixed_point_fires_on_dtype_drift():
    def tick(cache, tok):
        new = {"kv": cache["kv"].astype(jnp.bfloat16) + 1}   # the drift
        return new, tok

    cache = {"kv": SDS((2, 16), jnp.float32)}
    viols = check_carry_fixed_point(tick, (cache, SDS((2,), jnp.int32)),
                                    {0: 0}, point="tick")
    assert len(viols) == 1
    v = viols[0]
    assert v.check == "carry_dtype"
    assert "'kv'" in v.message and "float32" in v.message \
        and "bfloat16" in v.message and "retrace" in v.message


def test_carry_pass_flags_reintroduced_block_decode_drift(monkeypatch):
    """Reintroduce the PR 5 bug: ``mamba2.block_decode`` returning the conv
    tail in the activation dtype instead of the carried state's canonical
    dtype. The carry-dtype pass must flag the engine tick statically."""
    cfg = reduced(get_config("mamba2-2.7b"), layers=2, d_model=32, vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, policy=FLOAT, slots=2, max_len=32,
                        dtype=jnp.float32)
    point = next(p for p in eng.contract_points()
                 if p["name"] == "decode_tick")
    assert not check_carry_fixed_point(point["fn"], point["args"],
                                       point["carry"], point="decode_tick")

    real = mamba2.block_decode

    def drifting(lp, h_in, state, cfg, **kw):
        h, st = real(lp, h_in, state, cfg, **kw)
        return h, dict(st, conv=st["conv"].astype(jnp.bfloat16))

    monkeypatch.setattr(mamba2, "block_decode", drifting)
    viols = check_carry_fixed_point(point["fn"], point["args"],
                                    point["carry"], point="decode_tick")
    assert viols, "the reintroduced bf16 conv drift must be flagged"
    assert any("conv" in v.message and "bfloat16" in v.message
               for v in viols)


# --- pass 5: donation --------------------------------------------------------------

def test_donation_fires_when_dtype_drift_defeats_aliasing():
    def bad(c):
        return {"buf": c["buf"].astype(jnp.bfloat16)}

    viols = check_donation(bad, ({"buf": SDS((128,), jnp.float32)},), (0,),
                           point="tick")
    assert viols and all(v.check == "donation" for v in viols)
    assert any("copy" in v.message for v in viols)

    def good(c):
        return {"buf": c["buf"] + 1}

    assert not check_donation(good, ({"buf": SDS((128,), jnp.float32)},),
                              (0,), point="tick")


# --- pass 6: vmem_budget -----------------------------------------------------------

def test_vmem_budget_fires_on_oversized_blockspec():
    from jax.experimental import pallas as pl

    def copy_kernel(x_ref, o_ref):
        o_ref[:] = x_ref[:]

    n = 2048                        # (2048, 2048) f32 = 16 MiB per ref
    big = jax.make_jaxpr(lambda x: pl.pallas_call(
        copy_kernel, out_shape=SDS((n, n), jnp.float32))(x))(
            SDS((n, n), jnp.float32))
    viols = check_vmem_budget(big)  # default budget: one core's ~16 MiB
    assert len(viols) == 1
    v = viols[0]
    assert v.check == "vmem_budget" and "copy_kernel" in v.message
    assert "exceeds budget" in v.message and "2048" in v.message
    # the same kernel fits a loose budget
    assert not check_vmem_budget(big, budget_bytes=256 * 1024 * 1024)


def test_vmem_estimates_real_kernel():
    """The estimator reads a real serve kernel's footprint off its traced
    eqn: nonzero, and under the default budget for the reduced config."""
    from repro.analysis.jaxpr_utils import find_pallas_eqns
    from repro.analysis.vmem import pallas_vmem_estimate

    leaf = _serve_leaf()
    jx = jax.make_jaxpr(lambda x: quant_dense.serve_apply(
        leaf, x, mode="kernel", interpret=True))(SDS((8, 48), jnp.float32))
    eqns = find_pallas_eqns(jx)
    assert eqns
    est = pallas_vmem_estimate(eqns[0])
    assert est["vmem_bytes"] > 0 and est["grid"]
    assert not check_vmem_budget(jx)


# --- registry sweep + retrace budgets ----------------------------------------------

def test_lint_combo_clean_on_dense_q_kernel():
    """One full registry combo holds every contract (the CI gate sweeps
    all 16; this is the in-suite smoke)."""
    recs = lint_combo("dense", "q", "kernel")
    bad = {(r["point"], c): v for r in recs
           for c, v in r["checks"].items() if v}
    assert not bad, bad
    names = {r["point"] for r in recs}
    assert {"decode_tick", "prefill_bucketed", "admit_many", "spec_tick",
            "verify", "generate_loop"} <= names
    # kernel mode attaches per-kernel VMEM estimates to the report
    assert any(r.get("kernels") for r in recs)


def test_forbidden_shapes_cover_stacked_and_sliced():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=32, vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    shapes = forbidden_dequant_shapes(params, W3)
    assert shapes
    assert any(len(sh) == 2 for sh in shapes)        # per-layer (K, N)
    assert any(len(sh) == 3 for sh in shapes)        # stacked (L, K, N)


def test_retrace_report_budgets():
    class FakeEngine:
        def trace_counts(self):
            return {"tick": 3, "prefill": 1}

    rep = retrace_report(FakeEngine(), budgets={"tick": 1, "prefill": 2})
    assert rep["counts"] == {"tick": 3, "prefill": 1}
    assert len(rep["violations"]) == 1
    assert "tick" in rep["violations"][0]["message"]
    assert "3 traces" in rep["violations"][0]["message"]


def test_violation_str_carries_eqn():
    v = Violation("no_dequant", "msg", eqn="dot_general -> f32[4, 4]")
    assert "no_dequant: msg [at: dot_general -> f32[4, 4]]" == str(v)
    assert dataclasses.asdict(v) == v.to_dict()
