"""HLO collective parser + roofline reconstruction math.

The parser lives in ``repro.analysis.hlo`` (the static-analysis
subsystem's compiled-artifact backend); ``repro.launch.hlo_analysis``
stays importable as a compat shim — both are exercised here."""
import numpy as np

from benchmarks import roofline as rl
from repro.analysis.hlo import collective_bytes, _shape_bytes

HLO = """
HloModule test

%fused (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
}

ENTRY %main (p0: f32[128,256], p1: bf16[64]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = bf16[64]{0} parameter(1)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}
  %ag = bf16[256]{0} all-gather(%p1), dimensions={0}
  %rs = f32[32,256]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
  ROOT %out = f32[128,256]{1,0} add(%ar, %cp)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[64]") == 128
    assert _shape_bytes("s8[10,10]") == 100
    assert _shape_bytes("pred[8]") == 8


def test_shape_bytes_packed_dtypes():
    """The packed serve forms put sub-byte and 8-bit codes on the wire:
    s4/u4 are bit-packed two per byte, every f8 variant is one byte."""
    assert _shape_bytes("s4[128,256]") == 128 * 256 // 2
    assert _shape_bytes("u4[16]") == 8
    assert _shape_bytes("u8[100]") == 100
    assert _shape_bytes("f8e4m3fn[32,32]") == 32 * 32
    assert _shape_bytes("f8e5m2[64]") == 64


def test_hlo_analysis_compat_shim():
    """repro.launch.hlo_analysis re-exports the moved implementation."""
    from repro.analysis import hlo
    from repro.launch import hlo_analysis
    assert hlo_analysis.collective_bytes is hlo.collective_bytes
    assert hlo_analysis._shape_bytes is hlo._shape_bytes
    assert hlo_analysis.DTYPE_BYTES is hlo.DTYPE_BYTES


def test_collective_parser_counts_operands():
    out = collective_bytes(HLO)
    assert out["count"] == 4
    assert out["all-reduce"] == 128 * 256 * 4          # operand p0
    assert out["all-gather"] == 64 * 2                 # operand p1 (bf16[64])
    assert out["reduce-scatter"] == 128 * 256 * 4      # operand = ar's shape
    assert out["collective-permute"] == 128 * 256 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "collective-permute"))


def test_depth_combine_linear():
    rec = {"num_layers": 10, "attn_every": 0,
           "L0": {"cost": {"flops": 5.0, "bytes": 7.0},
                  "collectives": {"total": 1.0}},
           "L1": {"cost": {"flops": 8.0, "bytes": 10.0},
                  "collectives": {"total": 1.5}}}
    out = rl._depth_combine(rec)
    assert out["flops"] == 5.0 + 10 * 3.0
    assert out["bytes"] == 7.0 + 10 * 3.0
    assert out["coll"] == 1.0 + 10 * 0.5


def test_hybrid_combine_solves_attention_and_mamba():
    # synthetic: base 2, mamba layer m=3, attn block a=5, A=4, L=10 (G=2,T=2)
    base, m, a, A, L = 2.0, 3.0, 5.0, 4, 10
    rec = {"num_layers": L, "attn_every": A,
           "L0": {"cost": {"flops": base, "bytes": 0}, "collectives": {}},
           "G1": {"cost": {"flops": base + A * m + a, "bytes": 0},
                  "collectives": {}},
           "A1": {"cost": {"flops": base + m + a, "bytes": 0},
                  "collectives": {}}}
    out = rl._depth_combine(rec)
    g, tail = L // A, L % A
    expect = base + g * (A * m + a) + tail * m
    np.testing.assert_allclose(out["flops"], expect)


def test_quad_extrapolation_exact_for_quadratics():
    f = lambda s: 3.0 + 0.5 * s + 0.002 * s * s
    xs = [2048, 4096, 8192]
    got = rl._quad_extrapolate(xs, [f(x) for x in xs], 32768)
    np.testing.assert_allclose(got, f(32768), rtol=1e-12)


def test_model_flops_decode_vs_train():
    rec = {"arch": "qwen2-1.5b", "kind": "decode", "global_batch": 128,
           "seq_len": 32768, "params": 1.5e9, "active_params": 1.5e9}
    d = rl.model_flops_per_step(rec)
    rec2 = dict(rec, kind="train", global_batch=256, seq_len=4096)
    t = rl.model_flops_per_step(rec2)
    assert t / d > 1e4            # train moves vastly more flops per step
