"""The paper's 3-step pipeline (reduced size): end-to-end invariants."""
import pytest

from repro.paper.pipeline import PaperRunConfig, run_paper_experiment


@pytest.fixture(scope="module")
def digit_result():
    rc = PaperRunConfig(task="digit", hidden=(64, 64, 64), pretrain_epochs=3,
                        float_epochs=6, retrain_epochs=4)
    return run_paper_experiment(rc, log=lambda s: None)


def test_pipeline_trains(digit_result):
    assert digit_result["float_mcr"] < 35.0


def test_retraining_recovers_quantization_loss(digit_result):
    """Paper's core claim shape: retrained W3A8 ~ float, direct quant worse."""
    m = digit_result
    assert m["w3a8_mcr"] <= m["direct_quant_mcr"] + 1e-9
    assert m["w3a8_mcr"] - m["float_mcr"] < 15.0   # reduced-size loose bound


def test_packed_deployment_exact(digit_result):
    assert digit_result["packed_max_err"] < 1e-4


def test_onchip_compression_ratio(digit_result):
    """~9.8x smaller than fp32 (3-bit hidden + 8-bit output + fp32 biases) —
    the 'fits in BRAM' property (paper Table 1)."""
    ratio = digit_result["weight_bytes_float"] / digit_result["weight_bytes_packed"]
    assert ratio > 8.0
