"""Fused Pallas decode-attention kernel (kernels/attn_decode, interpret
mode): parity against its pure-jnp oracle (ref.py) and the production
einsum path (models.attention.decode_attention), bf16-class and int8
caches, per-row valid lengths, blocking edge cases, and the
``attn_mode`` dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attn_decode.ops import attn_decode
from repro.kernels.attn_decode.ref import attn_decode_ref
from repro.models.attention import (decode_attention, resolve_attn_mode,
                                    ATTN_MODES)
from repro.models.transformer import _quantize_kv


def _case(seed, b, s, h, kv, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, s, kv, d))
    vc = jax.random.normal(ks[2], (b, s, kv, d))
    return q, kc, vc


@pytest.mark.parametrize("h,kv", [(8, 8), (8, 2), (4, 1)])
@pytest.mark.parametrize("bm,bs", [(8, 128), (2, 32), (3, 17)])
def test_kernel_matches_ref_and_einsum(h, kv, bm, bs):
    """Mixed per-row lengths (incl. 1 and full): kernel == ref == einsum.
    bm/bs sweep covers B and S not divisible by the block sizes."""
    b, s, d = 5, 100, 16
    q, kc, vc = _case(0, b, s, h, kv, d)
    lens = jnp.asarray([1, 7, 64, 100, 33], jnp.int32)
    out = attn_decode(q, kc, vc, lens, bm=bm, bs=bs, interpret=True)
    ref = attn_decode_ref(q, kc, vc, lens)
    ein = decode_attention(q, kc, vc, lens, mode="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ein), atol=2e-5)


def test_kernel_scalar_cache_len():
    q, kc, vc = _case(1, 4, 64, 8, 2, 16)
    out = attn_decode(q, kc, vc, 42, interpret=True)
    ein = decode_attention(q, kc, vc, 42, mode="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ein), atol=2e-5)


def test_kernel_int8_cache_with_scales():
    """int8 K/V + per-token scales read directly: the fused dequant
    epilogue must factor the scales exactly where decode_attention does."""
    b, s = 5, 80
    q, kc, vc = _case(2, b, s, 8, 2, 16)
    kq, ksc = _quantize_kv(kc)
    vq, vsc = _quantize_kv(vc)
    lens = jnp.asarray([1, 80, 13, 37, 64], jnp.int32)
    out = attn_decode(q, kq, vq, lens, ksc, vsc, bm=2, bs=32, interpret=True)
    ref = attn_decode_ref(q, kq, vq, lens, ksc, vsc)
    ein = decode_attention(q, kq, vq, lens, ksc, vsc, mode="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ein), atol=2e-5)
    # and the int8 path is actually close to the float attention it encodes
    full = decode_attention(q, kc, vc, lens, mode="ref")
    assert float(jnp.max(jnp.abs(out - full))) < 0.1


def test_kernel_bf16_cache():
    q, kc, vc = _case(3, 4, 64, 8, 2, 16)
    kc16, vc16 = kc.astype(jnp.bfloat16), vc.astype(jnp.bfloat16)
    lens = jnp.asarray([5, 64, 17, 50], jnp.int32)
    out = attn_decode(q, kc16, vc16, lens, interpret=True)
    ein = decode_attention(q, kc16, vc16, lens, mode="ref")
    assert out.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ein, np.float32), atol=2e-2)


def test_kernel_ring_permutation_invariance():
    """Ring-buffer storage order must not change the kernel's output
    (mirrors the einsum-path test in test_attention.py)."""
    b, l, h, kv, d = 1, 16, 4, 4, 8
    q, kc, vc = _case(4, b, l, h, kv, d)
    out1 = attn_decode(q, kc, vc, jnp.full((b,), l), interpret=True)
    perm = jax.random.permutation(jax.random.PRNGKey(9), l)
    out2 = attn_decode(q, kc[:, perm], vc[:, perm], jnp.full((b,), l),
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=2e-5)


def test_zero_length_rows_are_zero():
    """cache_len == 0 rows (engine padding) produce zeros, not NaN or the
    uniform v average — both kernel and ref guard the empty softmax."""
    q, kc, vc = _case(5, 3, 32, 4, 2, 8)
    lens = jnp.asarray([0, 16, 0], jnp.int32)
    out = attn_decode(q, kc, vc, lens, interpret=True)
    ref = attn_decode_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    assert not np.any(np.isnan(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[2]), 0.0)
    assert float(jnp.max(jnp.abs(out[1]))) > 0


def test_attn_mode_dispatch():
    """decode_attention(mode=...) mirrors quant_dense.serve_apply: 'kernel'
    routes to the Pallas kernel, 'ref' to the einsum path, 'auto' resolves
    by backend, junk raises."""
    assert resolve_attn_mode("auto") in ("kernel", "ref")
    assert resolve_attn_mode("kernel") == "kernel"
    assert resolve_attn_mode("ref") == "ref"
    with pytest.raises(ValueError):
        resolve_attn_mode("einsum")
    assert "auto" in ATTN_MODES
    q, kc, vc = _case(6, 2, 40, 8, 2, 16)
    lens = jnp.asarray([11, 40], jnp.int32)
    out_k = decode_attention(q, kc, vc, lens, mode="kernel", interpret=True)
    out_r = decode_attention(q, kc, vc, lens, mode="ref")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5)
