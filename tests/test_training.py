"""Training substrate: loss decreases, microbatch equivalence, checkpoint
restart, optimizer math, straggler monitor."""
import os
import tempfile

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt_lib
from repro import optim as optim_lib
from repro.configs import TrainConfig, get_config, reduced
from repro.core.precision import FLOAT, W3A8
from repro.data.pipeline import HostLoader, prefetch
from repro.data.synthetic import lm_batch
from repro.models import get_model
from repro.training.loop import StragglerMonitor, make_train_step


def _tiny():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=32, vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _loader(cfg, batch=8, seq=16):
    return HostLoader(lambda seed, step: lm_batch(
        jnp.asarray(seed), jnp.asarray(step), batch=batch, seq=seq,
        vocab=cfg.vocab_size))


def test_loss_decreases():
    cfg, params = _tiny()
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=40, warmup_steps=4)
    step, init_state = make_train_step(cfg, tcfg, FLOAT, dtype=jnp.float32)
    step = jax.jit(step)
    state = init_state(params)
    it = iter(_loader(cfg))
    losses = []
    for _ in range(40):
        state, m = step(state, next(it))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


def test_qat_trains_without_nan():
    cfg, params = _tiny()
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=2)
    step, init_state = make_train_step(cfg, tcfg, W3A8, dtype=jnp.float32)
    step = jax.jit(step)
    state = init_state(params)
    it = iter(_loader(cfg))
    for _ in range(5):
        state, m = step(state, next(it))
        assert jnp.isfinite(m["loss"])


def test_microbatch_equivalence():
    """2 microbatches == 1 big batch (same grads up to fp tolerance).

    Gradients are already accumulated in float32 (training/loop.py zeros_g);
    the residual mismatch is pure reduction-order noise: the xent mean over 8
    rows vs mean-of-two-4-row-means reassociates fp32 sums, and Adam's
    rsqrt(v) normalization amplifies that ~1e-9 grad difference on
    near-zero-gradient parameters into ~4e-6 parameter deltas after one
    lr=1e-2 step. atol=1e-5 absorbs that while still catching real
    accumulation bugs (a missing 1/n rescale shifts params by O(lr)=1e-2,
    three orders of magnitude above the tolerance)."""
    cfg, params = _tiny()
    batch = next(iter(_loader(cfg, batch=8)))
    out = {}
    for n in (1, 2):
        tcfg = TrainConfig(learning_rate=1e-2, microbatches=n,
                           total_steps=10, warmup_steps=0)
        step, init_state = make_train_step(cfg, tcfg, FLOAT, dtype=jnp.float32)
        state, m = jax.jit(step)(init_state(params), batch)
        out[n] = (jax.flatten_util.ravel_pytree(state["params"])[0],
                  float(m["loss"]))
    np.testing.assert_allclose(out[1][1], out[2][1], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1][0]), np.asarray(out[2][0]),
                               rtol=2e-4, atol=1e-5)


def test_checkpoint_restart_bitexact():
    """Kill-and-restart: trainer resumed from step k matches uninterrupted."""
    cfg, params = _tiny()
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=20, warmup_steps=0)
    step, init_state = make_train_step(cfg, tcfg, FLOAT, dtype=jnp.float32)
    step = jax.jit(step)

    def run(state, loader, n):
        it = iter(loader)
        for _ in range(n):
            state, _ = step(state, next(it))
        return state

    # uninterrupted 10 steps
    s_full = run(init_state(params), _loader(cfg), 10)
    with tempfile.TemporaryDirectory() as td:
        s5 = run(init_state(params), _loader(cfg), 5)
        ckpt_lib.save(td, 5, s5)
        tree, meta = ckpt_lib.restore(td)
        s_resumed = jax.tree_util.tree_map(jnp.asarray, tree)
        loader = HostLoader(lambda seed, step_: lm_batch(
            jnp.asarray(seed), jnp.asarray(step_), batch=8, seq=16,
            vocab=cfg.vocab_size), start_step=5)
        s_resumed = run(s_resumed, loader, 5)
    a = jax.flatten_util.ravel_pytree(s_full["params"])[0]
    b = jax.flatten_util.ravel_pytree(s_resumed["params"])[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_checkpoint_keep_k_and_atomicity():
    with tempfile.TemporaryDirectory() as td:
        for s in (1, 2, 3, 4):
            ckpt_lib.save(td, s, {"x": jnp.ones((3,)) * s}, keep=2)
        assert ckpt_lib.all_steps(td) == [3, 4]
        # a stale tmp dir must be ignored by restore
        os.makedirs(os.path.join(td, "step_000000000099.tmp"))
        assert ckpt_lib.latest_step(td) == 4
        tree, meta = ckpt_lib.restore(td)
        np.testing.assert_allclose(np.asarray(tree["x"]), 4.0)


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as td:
        ck = ckpt_lib.Checkpointer(td, keep=3)
        ck.save_async(1, {"w": jnp.arange(4.0)})
        ck.wait()
        tree, _ = ckpt_lib.restore(td, 1)
        np.testing.assert_allclose(np.asarray(tree["w"]), np.arange(4.0))


def test_sgd_momentum_matches_paper_form():
    """mu <- 0.9 mu + g ; p <- p - lr mu."""
    opt = optim_lib.sgd(momentum=0.9)
    p = {"w": jnp.ones((2,))}
    st = opt.init(p)
    g = {"w": jnp.full((2,), 2.0)}
    up1, st = opt.update(g, st, p, 0.1)
    np.testing.assert_allclose(np.asarray(up1["w"]), 0.2)
    up2, st = opt.update(g, st, p, 0.1)
    np.testing.assert_allclose(np.asarray(up2["w"]), 0.1 * (0.9 * 2 + 2))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    clipped, norm = optim_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(4 * 9 + 9 * 16))
    n2 = optim_lib.global_norm(clipped)
    assert float(n2) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_schedule():
    sched = optim_lib.warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0)
    assert float(sched(5)) == pytest.approx(0.5)
    assert float(sched(110)) < 0.2


def test_prefetch_preserves_order_and_errors():
    assert list(prefetch(iter(range(10)), 3)) == list(range(10))

    def bad():
        yield 1
        raise RuntimeError("boom")

    it = prefetch(bad(), 2)
    assert next(it) == 1
    with pytest.raises(RuntimeError):
        list(it)


def test_straggler_monitor():
    m = StragglerMonitor(factor=2.0)
    for _ in range(10):
        m.record(0.1)
    assert not m.record(0.15)
    assert m.record(0.5)       # 5x EMA -> straggler
    assert m.slow_steps == 1
    # straggler did not pollute the EMA
    assert m.ema == pytest.approx(0.1, rel=0.2)
