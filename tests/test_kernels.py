"""Per-kernel allclose vs pure-jnp oracles (interpret=True on CPU), with
hypothesis shape/dtype sweeps as required for every Pallas kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # optional dev dep; see requirements-dev.txt
    from _hypothesis_stub import given, settings, st

from repro.core.packing import pack_matrix
from repro.kernels.qmatmul.ops import qmatmul
from repro.kernels.qmatmul.ref import qmatmul_ref
from repro.kernels.qmatvec.ops import qmatvec
from repro.kernels.qmatvec.ref import qmatvec_ref
from repro.kernels.sigmoid_pw.kernel import sigmoid_pw_pallas
from repro.kernels.sigmoid_pw.ref import sigmoid_pw


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestQMatmul:
    @pytest.mark.parametrize("m,k,n", [(8, 32, 16), (128, 128, 128),
                                       (100, 1022, 10), (257, 513, 129)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, m, k, n, dtype):
        kx, kw, kd = jax.random.split(jax.random.PRNGKey(0), 3)
        x = _rand(kx, (m, k), dtype)
        wq = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
        d = jnp.abs(_rand(kd, (n,), jnp.float32)) * 0.1 + 0.01
        out = qmatmul(x, wq, d, interpret=True)
        ref = qmatmul_ref(x, wq, d)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(np.asarray(out, jnp.float32),
                                   np.asarray(ref, jnp.float32),
                                   rtol=tol, atol=tol)

    def test_batched_leading_dims(self):
        x = _rand(jax.random.PRNGKey(0), (2, 3, 64), jnp.float32)
        wq = jax.random.randint(jax.random.PRNGKey(1), (64, 32), -3, 4, jnp.int8)
        d = jnp.ones((32,), jnp.float32) * 0.1
        out = qmatmul(x, wq, d, interpret=True)
        assert out.shape == (2, 3, 32)
        ref = qmatmul_ref(x.reshape(-1, 64), wq, d).reshape(2, 3, 32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 70), st.integers(1, 150), st.integers(1, 70),
           st.integers(0, 2**31 - 1))
    def test_shape_sweep_property(self, m, k, n, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = _rand(ks[0], (m, k), jnp.float32)
        wq = jax.random.randint(ks[1], (k, n), -3, 4, jnp.int8)
        d = jnp.abs(_rand(ks[2], (n,), jnp.float32)) * 0.1 + 0.01
        out = qmatmul(x, wq, d, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(qmatmul_ref(x, wq, d)),
                                   rtol=1e-4, atol=1e-4)


class TestQMatvec:
    @pytest.mark.parametrize("b,k,n", [(1, 1022, 1022), (8, 100, 64),
                                       (128, 640, 256)])
    def test_vs_ref(self, b, k, n):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = _rand(ks[0], (b, k), jnp.float32)
        q = jax.random.randint(ks[1], (k, n), -3, 4, jnp.int8)
        wp = pack_matrix(q, 3)
        d = jnp.abs(_rand(ks[2], (n,), jnp.float32)) * 0.1 + 0.01
        out = qmatvec(x, wp, d, k=k, interpret=True)
        ref = qmatvec_ref(x, wp, d, k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 16), st.integers(1, 200), st.integers(1, 64),
           st.integers(0, 2**31 - 1))
    def test_shape_sweep_property(self, b, k, n, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = _rand(ks[0], (b, k), jnp.float32)
        q = jax.random.randint(ks[1], (k, n), -3, 4, jnp.int8)
        wp = pack_matrix(q, 3)
        d = jnp.abs(_rand(ks[2], (n,), jnp.float32)) * 0.1 + 0.01
        out = qmatvec(x, wp, d, k=k, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(qmatvec_ref(x, wp, d, k)),
                                   rtol=1e-4, atol=1e-4)

    def test_packed_traffic_is_3p2_bits(self):
        k, n = 1000, 64
        q = jnp.zeros((k, n), jnp.int8)
        wp = pack_matrix(q, 3)
        assert wp.nbytes * 8 / (k * n) == pytest.approx(3.2, rel=0.01)


class TestFusedBias:
    """Batched decode/prefill shapes with the bias fused into the kernel
    epilogue, checked against the dequantized ``effective_weight`` oracle
    (the serve-path correctness bar)."""

    def _oracle(self, x, leaf):
        from repro.core import quant_dense
        from repro.core.precision import W3A8
        w = quant_dense.effective_weight(leaf, W3A8, "hidden", k=x.shape[-1])
        return x @ w.astype(x.dtype) + leaf["b"]

    @pytest.mark.parametrize("b", [2, 8, 128])      # decode + prefill shapes
    def test_qmatvec_batched_with_bias_vs_effective_weight(self, b):
        k, n = 100, 64
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        x = _rand(ks[0], (b, k), jnp.float32)
        q = jax.random.randint(ks[1], (k, n), -3, 4, jnp.int8)
        d = jnp.abs(_rand(ks[2], (n,), jnp.float32)) * 0.1 + 0.01
        bias = _rand(ks[3], (n,), jnp.float32)
        leaf = {"qp": pack_matrix(q, 3), "delta": d.reshape(1, n), "b": bias}
        out = qmatvec(x, leaf["qp"], d, k=k, bias=bias, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(self._oracle(x, leaf)),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("b", [8, 128])
    def test_qmatmul_levels_with_bias_vs_effective_weight(self, b):
        k, n = 100, 64
        ks = jax.random.split(jax.random.PRNGKey(4), 4)
        x = _rand(ks[0], (b, k), jnp.float32)
        q = jax.random.randint(ks[1], (k, n), -3, 4, jnp.int8)
        d = jnp.abs(_rand(ks[2], (n,), jnp.float32)) * 0.1 + 0.01
        bias = _rand(ks[3], (n,), jnp.float32)
        leaf = {"q": q, "delta": d.reshape(1, n), "b": bias}
        out = qmatmul(x, q, d, bias=bias, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(self._oracle(x, leaf)),
                                   rtol=1e-4, atol=1e-4)


class TestSigmoidPW:
    def test_vs_ref_and_exact(self):
        x = jnp.linspace(-8, 8, 1000)
        out = sigmoid_pw_pallas(x, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(sigmoid_pw(x)),
                                   atol=1e-6)
        # PLAN approximation error bound vs exact sigmoid
        err = float(jnp.max(jnp.abs(out - jax.nn.sigmoid(x))))
        assert err < 0.0190

    @pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 3, 129)])
    def test_shapes(self, shape):
        x = jax.random.normal(jax.random.PRNGKey(0), shape) * 4
        out = sigmoid_pw_pallas(x, interpret=True)
        assert out.shape == shape

    def test_symmetry(self):
        x = jnp.linspace(0.0, 6.0, 100)
        lo = sigmoid_pw_pallas(-x, interpret=True)
        hi = sigmoid_pw_pallas(x, interpret=True)
        np.testing.assert_allclose(np.asarray(lo + hi), 1.0, atol=1e-6)
