"""Weight-store integrity + serving-state round-trips: CRC golden
manifests detect host-side corruption; the in-graph canary fingerprint
probe detects (and localizes) ANY single-bit flip in a protected leaf;
the engine's probe + self-heal path survives injected soft errors in the
packed container with outputs matching a clean run; and the durability
layer's array plumbing — ``cache_to_host``/``cache_from_host`` and the
checkpoint npz round-trip — is exact and dtype-preserving across all four
families x weight forms, including int8-KV scale trees, SWA ring state,
and bfloat16 leaves (which plain ``np.savez`` would silently degrade to
raw void bytes).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import integrity
from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.core.treeutil import flatten_with_path, tree_get, tree_set
from repro.models import api as model_api
from repro.models import get_model
from repro.serving.engine import ServingEngine
from repro.serving.resilience import FaultPlan

W3 = dataclasses.replace(W3A8, act_bits=None)


@pytest.fixture(scope="module", autouse=True)
def _drop_compile_caches():
    yield
    jax.clear_caches()


ARCH_FOR = {"dense": "qwen2-1.5b", "ssm": "mamba2-2.7b",
            "moe": "mixtral-8x22b", "hybrid": "zamba2-1.2b"}


def _setup(family="dense", form="qp"):
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    if form == "w":
        return cfg, params, FLOAT
    export = {"q": quant_dense.export_levels,
              "qp": quant_dense.export_container}[form]
    return cfg, export(params, W3), W3


def _flip_host(tree, path, bit):
    a = np.array(np.asarray(tree_get(tree, path)))
    raw = a.view(np.uint8).reshape(-1)
    b = bit % (raw.size * 8)
    raw[b // 8] ^= np.uint8(1 << (b % 8))
    return tree_set(tree, path, jnp.asarray(a))


# --- golden manifest ----------------------------------------------------------

@pytest.mark.parametrize("form", ["w", "q", "qp"])
def test_manifest_localizes_bit_flip(form):
    """verify_manifest names exactly the corrupted container — serve
    forms protect the packed qp/q/delta leaves, float masters every
    array leaf."""
    _, params, _ = _setup("dense", form)
    paths = integrity.protected_paths(params)
    assert paths
    if form == "qp":
        # the embedding stays in level form even in the packed export
        assert all(p.rsplit("/", 1)[-1] in ("qp", "q", "delta")
                   for p in paths)
    if form == "q":
        assert all(p.rsplit("/", 1)[-1] in ("q", "delta") for p in paths)
    manifest = integrity.build_manifest(params, paths)
    assert integrity.verify_manifest(params, manifest) == []
    victim = paths[len(paths) // 2]
    bad = _flip_host(params, victim, 12345)
    assert integrity.verify_manifest(bad, manifest) == [victim]


def test_manifest_save_load_roundtrip(tmp_path):
    _, params, _ = _setup("dense", "qp")
    manifest = integrity.build_manifest(params)
    p = str(tmp_path / "m" / "manifest.json")
    integrity.save_manifest(p, manifest)
    assert integrity.load_manifest(p) == manifest


# --- in-graph canary probe ----------------------------------------------------

@pytest.mark.parametrize("form", ["w", "qp"])
def test_probe_detects_any_single_bit(form):
    """The wrapping-uint32 odd-multiplier fingerprint moves for EVERY
    single-bit flip — across leaves, word positions, and bit positions
    (incl. the high bit, which a float dot product would round away) —
    and returns to golden when the flip is undone."""
    _, params, _ = _setup("dense", form)
    paths, probe = integrity.make_probe(params)
    probe = jax.jit(probe)
    golden = np.asarray(probe(params))
    rng = np.random.default_rng(0)
    for trial in range(12):
        i = int(rng.integers(len(paths)))
        bit = int(rng.integers(1 << 20))
        bad = _flip_host(params, paths[i], bit)
        fps = np.asarray(probe(bad))
        diff = np.nonzero(fps != golden)[0]
        assert list(diff) == [i], \
            f"flip of bit {bit} in {paths[i]} not localized (diff={diff})"
        # flipping the same bit back restores the fingerprint exactly
        assert np.array_equal(np.asarray(probe(_flip_host(bad, paths[i],
                                                          bit))), golden)


def test_probe_matches_manifest_verdict():
    """The cheap in-graph probe and the exact host CRC oracle agree on
    clean and corrupted stores."""
    _, params, _ = _setup("dense", "qp")
    paths, probe = integrity.make_probe(params)
    manifest = integrity.build_manifest(params, paths)
    golden = integrity.fingerprints(params, paths)
    bad = _flip_host(params, paths[0], 7)
    assert integrity.verify_manifest(bad, manifest) == [paths[0]]
    fps = integrity.fingerprints(bad, paths)
    assert [paths[i] for i in np.nonzero(fps != golden)[0]] == [paths[0]]


def test_golden_store_roundtrip(tmp_path):
    """save_golden/load_golden: exact bytes and dtypes back, manifest
    attached — what the engine heals from."""
    _, params, _ = _setup("dense", "qp")
    gdir = str(tmp_path / "golden")
    manifest = integrity.save_golden(gdir, params)
    flat, manifest2 = integrity.load_golden(gdir)
    assert manifest2 == manifest
    for p in integrity.protected_paths(params):
        want = np.asarray(tree_get(params, p))
        assert flat[p].dtype == want.dtype
        assert np.array_equal(flat[p], want)


# --- engine probe + self-heal -------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_engine_detects_and_heals_bit_flip(tmp_path, family):
    """A soft error injected into a packed container mid-run is detected
    by the periodic canary probe, healed from the golden copy, the
    affected in-flight requests are rewound and requeued, and the run
    completes with output identical to a clean run."""
    cfg, params, policy = _setup(family, "qp")
    prompts = [[1, 2, 3], [7, 8, 9, 10], [20, 21], [30, 31, 32, 33, 34]]
    maxnew = [7, 5, 8, 6]

    def run(**kw):
        eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=64,
                            dtype=jnp.float32, **kw)
        for p, m in zip(prompts, maxnew):
            eng.submit(list(p), max_new=m)
        done = eng.run_all(max_ticks=600)
        return eng, {r.uid: (tuple(r.prompt), tuple(r.out)) for r in done}

    _, clean = run()
    victim = [p for p in flatten_with_path(params) if p.endswith("/qp")][0]
    eng, healed = run(integrity_every=1, golden_dir=str(tmp_path / "g"),
                      fault_plan=FaultPlan(flip_bits=[(5, victim, 31337)]))
    assert eng.heal_count == 1
    assert any(lbl == f"heal:{victim}" for _, lbl in eng.fallback_events)
    assert eng.integrity_probes > 1
    # post-heal the store matches its manifest again (exact host oracle)
    assert integrity.verify_manifest(eng.params, eng._manifest) == []
    assert healed == clean
    # the golden store was persisted for out-of-process heals too
    flat, _ = integrity.load_golden(str(tmp_path / "g"))
    assert victim in flat


def test_heal_rewinds_in_flight_requests():
    """Corruption detected while requests are resident: every unfinished
    request is rolled back to its prompt (suspect tokens discarded) and
    requeued — statuses stay 'ok' and nothing is lost."""
    cfg, params, policy = _setup("dense", "qp")
    victim = [p for p in flatten_with_path(params) if p.endswith("/qp")][0]
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=64,
                        dtype=jnp.float32, integrity_every=1,
                        fault_plan=FaultPlan(flip_bits=[(3, victim, 9)]))
    uids = [int(eng.submit([i + 1, i + 2, i + 3], max_new=6))
            for i in range(4)]
    done = eng.run_all(max_ticks=600)
    assert sorted(r.uid for r in done) == uids
    assert all(r.status == "ok" for r in done)
    assert all(len(r.out) == 6 for r in done)
    assert eng.heal_count == 1


def test_integrity_probe_off_by_default():
    cfg, params, policy = _setup("dense", "qp")
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32)
    eng.submit([1, 2, 3], max_new=3)
    eng.run_all(max_ticks=100)
    assert eng.integrity_probes == 0 and eng._probe_paths is None


# --- serving-state array round-trips ------------------------------------------

CACHE_CASES = [("dense", "w", None), ("dense", "q", None),
               ("dense", "qp", 8), ("ssm", "w", None), ("ssm", "qp", None),
               ("moe", "qp", None), ("hybrid", "qp", None),
               ("hybrid", "qp", 8)]


@pytest.mark.parametrize("family,form,kv_bits", CACHE_CASES)
def test_cache_roundtrip_exact(tmp_path, family, form, kv_bits):
    """cache_to_host -> checkpoint.save/restore -> cache_from_host is the
    identity on a LIVE mid-run cache: exact array equality and preserved
    dtypes for every leaf — KV (incl. int8 levels + scale trees), SSM
    state, hybrid groups, and the SWA ring (moe = mixtral, sliding
    window)."""
    from repro import checkpoint
    cfg, params, policy = _setup(family, form)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=48,
                        dtype=jnp.float32, kv_bits=kv_bits)
    eng.submit([1, 2, 3, 4, 5], max_new=6)
    eng.submit([9, 8, 7], max_new=5)
    for _ in range(3):
        eng.step()
    eng._sync()

    host = model_api.cache_to_host(cfg, eng.cache)
    checkpoint.save(str(tmp_path / "c"), 0, host)
    loaded, _ = checkpoint.restore(str(tmp_path / "c"), 0)
    back = model_api.cache_from_host(cfg, loaded, like=eng.cache)

    want = flatten_with_path(jax.device_get(eng.cache))
    got = flatten_with_path(jax.device_get(back))
    assert set(got) == set(want)
    for k in want:
        assert np.asarray(got[k]).dtype == np.asarray(want[k]).dtype, k
        assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), k


def test_cache_from_host_validates_against_like():
    """Structure/shape/dtype mismatches against the live cache are
    refused loudly, naming the offending leaf."""
    cfg, params, policy = _setup("dense", "qp")
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32)
    host = model_api.cache_to_host(cfg, eng.cache)
    bad = jax.tree_util.tree_map(lambda x: x, host)
    bad["k"] = bad["k"][..., :-1]                     # wrong shape
    with pytest.raises(ValueError):
        model_api.cache_from_host(cfg, bad, like=eng.cache)


def test_checkpoint_preserves_bf16(tmp_path):
    """The checkpoint npz path records true dtypes: bfloat16 leaves come
    back as bfloat16 with identical bits (np.savez alone would return raw
    '|V2' void bytes)."""
    from repro import checkpoint
    tree = {"a": jnp.arange(12, dtype=jnp.bfloat16) / 7,
            "n": {"b": jnp.ones((3, 2), jnp.float32),
                  "c": jnp.arange(5, dtype=jnp.int8)}}
    checkpoint.save(str(tmp_path / "c"), 0, tree)
    back, meta = checkpoint.restore(str(tmp_path / "c"), 0)
    assert "_dtypes" not in meta                      # internal, popped
    for k, v in flatten_with_path(tree).items():
        got = flatten_with_path(back)[k]
        assert got.dtype == np.asarray(v).dtype, k
        assert np.array_equal(got, np.asarray(v)), k
