"""Per-assigned-architecture smoke tests (deliverable f): a REDUCED config of
the same family runs one forward + one train step on CPU, asserting output
shapes and no NaNs. The FULL configs are exercised only via the dry-run."""
import jax
import jax.flatten_util
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, TrainConfig, get_config, reduced
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.models.frontends import synthetic_frontend_embeds, text_len
from repro.training.loop import make_train_step

B, S = 2, 16


def _batch(cfg, key, with_labels=False):
    st = text_len(cfg, S)
    out = {"tokens": jax.random.randint(key, (B, st), 0, cfg.vocab_size)}
    if with_labels:
        out["labels"] = jax.random.randint(key, (B, st), 0, cfg.vocab_size)
    if cfg.frontend:
        out["frontend_embeds"] = synthetic_frontend_embeds(key, cfg, B,
                                                           jnp.float32)
    return out


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("policy_name", ["float", "w3a8"])
def test_forward_smoke(arch, policy_name, key):
    cfg = reduced(get_config(arch))
    mod = get_model(cfg)
    params = mod.init(key, cfg)
    policy = FLOAT if policy_name == "float" else W3A8
    logits, aux = mod.forward(params, _batch(cfg, key), cfg, policy=policy,
                              dtype=jnp.float32)
    total = S if cfg.frontend else text_len(cfg, S)
    assert logits.shape == (B, total, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, key):
    cfg = reduced(get_config(arch))
    mod = get_model(cfg)
    params = mod.init(key, cfg)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=2,
                       remat="layer")
    step, init_state = make_train_step(cfg, tcfg, FLOAT, dtype=jnp.float32)
    state = init_state(params)
    state, metrics = step(state, _batch(cfg, key, with_labels=True))
    assert jnp.isfinite(metrics["loss"])
    assert not bool(jnp.any(jnp.isnan(
        jax.flatten_util.ravel_pytree(state["params"])[0])))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["qwen3-32b", "mixtral-8x22b", "mamba2-2.7b",
                                  "zamba2-1.2b", "internvl2-26b"])
def test_prefill_decode_smoke(arch, key):
    cfg = reduced(get_config(arch))
    mod = get_model(cfg)
    params = mod.init(key, cfg)
    toks = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)
    logits, cache = mod.prefill(params, {"tokens": toks}, cfg, policy=FLOAT,
                                dtype=jnp.float32, max_len=12)
    assert logits.shape == (B, 1, cfg.vocab_size)
    for _ in range(3):
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        logits, cache = mod.decode_step(params, cache, tok, cfg, policy=FLOAT,
                                        dtype=jnp.float32)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_all_archs_have_exact_assigned_dims():
    """Pin the assigned-architecture table (guards against config drift)."""
    expect = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }
    for arch, (l, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (l, d, h, kv, ff, v), arch
    # family-specific extras
    assert get_config("phi3.5-moe-42b-a6.6b").num_experts == 16
    assert get_config("mixtral-8x22b").num_experts == 8
    assert get_config("mamba2-2.7b").ssm_state == 128
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("qwen3-32b").qk_norm
    assert get_config("qwen2.5-14b").qkv_bias
