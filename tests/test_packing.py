"""Property tests for the sub-byte container format (the paper's BRAM image)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # optional dev dep; see requirements-dev.txt
    from _hypothesis_stub import given, settings, st

from repro.core import packing
from repro.core.quantizer import max_level


class TestFieldsPerWord:
    def test_paper_density(self):
        # 10 x 3-bit weights per 32-bit word — 2.5 weights/byte
        assert packing.fields_per_word(3) == 10
        assert packing.fields_per_word(2) == 16
        assert packing.fields_per_word(4) == 8
        assert packing.fields_per_word(8) == 4


@settings(max_examples=40, deadline=None)
@given(st.sampled_from([2, 3, 4, 8]), st.integers(1, 700),
       st.integers(0, 2**31 - 1))
def test_roundtrip_property(bits, n, seed):
    m = max_level(bits)
    q = jax.random.randint(jax.random.PRNGKey(seed), (n,), -m, m + 1,
                           dtype=jnp.int32).astype(jnp.int8)
    words = packing.pack_int32(q, bits)
    assert words.shape[0] == packing.packed_words(n, bits)
    back = packing.unpack_int32(words, n, bits)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 65), st.integers(1, 17), st.integers(0, 2**31 - 1))
def test_matrix_roundtrip_property(k, n, seed):
    q = jax.random.randint(jax.random.PRNGKey(seed), (k, n), -3, 4,
                           dtype=jnp.int32).astype(jnp.int8)
    words = packing.pack_matrix(q, 3)
    back = packing.unpack_matrix(words, k, 3)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_negative_min_level():
    """Full two's-complement range including -(2^(b-1)) packs fine."""
    q = jnp.array([-4, -3, 3, 0, -4, 1, 2, -1, -2, 3, -4], jnp.int8)
    back = packing.unpack_int32(packing.pack_int32(q, 3), q.shape[0], 3)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_out_of_range_levels_rejected():
    """Levels outside the b-bit range raise instead of silently truncating
    to a wrong-but-plausible weight (the documented pack contract)."""
    import pytest

    with pytest.raises(ValueError, match="out of range"):
        packing.pack_int32(jnp.array([0, 4, 1], jnp.int32), 3)   # 4 > 3
    with pytest.raises(ValueError, match="out of range"):
        packing.pack_int32(jnp.array([-5], jnp.int32), 3)        # -5 < -4
    with pytest.raises(ValueError, match="out of range"):
        packing.pack_matrix(jnp.full((4, 2), 9, jnp.int32), 3)
    # boundary values are legal
    packing.pack_int32(jnp.array([-4, 3], jnp.int32), 3)
    packing.pack_matrix(jnp.array([[-2], [1]], jnp.int32), 2)


def test_out_of_range_check_on_concrete_stacked_levels():
    """Under vmap/jit the levels are tracers and pack_matrix's own check
    cannot see them (it returns early) — the stacked-layer export path must
    therefore run the check on the CONCRETE stacked array before vmapping
    (quant_dense.export_container does). Pin both halves of that contract."""
    import jax
    import pytest

    bad = jnp.full((2, 4, 2), 9, jnp.int32)
    # the vmapped pack silently truncates (tracer: check unreachable)...
    packed = jax.vmap(lambda m: packing.pack_matrix(m, 3))(bad)
    back = jax.vmap(lambda w: packing.unpack_matrix(w, 4, 3))(packed)
    assert int(back[0, 0, 0]) != 9            # 9 -> low 3 bits = 1
    # ...so the concrete pre-check is what guards the export path
    with pytest.raises(ValueError, match="out of range"):
        packing._check_levels(bad, 3)


def test_packed_nbytes_compression():
    # 3M weights (paper digit net): packed ~1.2MB vs 11.6MB float32
    n = 2_903_512
    packed = packing.packed_nbytes((n,), 3)
    assert packed < n * 4 / 9      # >9x smaller than fp32
    assert packed >= n * 3 / 8 * 0.9
