"""STE retraining semantics (paper step 3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import qat
from repro.core.quantizer import QuantSpec


def test_ste_gradient_is_identity_in_range():
    spec = QuantSpec(bits=3)
    w = jnp.linspace(-0.5, 0.5, 31)
    delta = jnp.asarray(0.3)

    g = jax.grad(lambda x: jnp.sum(qat.fake_quant(x, spec, delta)))(w)
    # inside the clip range the STE passes gradient 1 (round is transparent)
    inside = jnp.abs(w / delta) < 3
    np.testing.assert_allclose(np.asarray(g[inside]), 1.0, atol=1e-6)


def test_fake_quant_forward_is_quantized():
    spec = QuantSpec(bits=3)
    w = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.2
    wq = qat.fake_quant(w, spec)
    # forward values lie on the 7-level grid {-3..3} x delta
    assert len(jnp.unique(wq)) <= 7


def test_fake_quant_act_unsigned_range():
    x = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (512,)))
    xq = qat.fake_quant_act(x, 8, signed=False)
    assert float(jnp.min(xq)) >= 0.0
    assert len(np.unique(np.asarray(xq))) <= 256
    np.testing.assert_allclose(np.asarray(xq), np.asarray(x), atol=1 / 255 + 1e-6)


def test_fake_quant_act_signed():
    x = jax.random.normal(jax.random.PRNGKey(1), (512,))
    xq = qat.fake_quant_act(x, 8, signed=True)
    scale = float(jnp.max(jnp.abs(x))) / 127
    np.testing.assert_allclose(np.asarray(xq), np.asarray(x), atol=scale + 1e-6)


def test_three_step_pipeline_order():
    calls = []

    def ft(p):
        calls.append("float")
        return p, {"m": 1}

    def qt(p):
        calls.append("quant")
        return {"d": 1}

    def rt(p, d):
        calls.append("retrain")
        assert d == {"d": 1}
        return p, {"m": 2}

    res = qat.three_step_pipeline({"w": 0}, ft, qt, rt)
    assert calls == ["float", "quant", "retrain"]
    assert res.retrain_metrics == {"m": 2}
