"""Overload-hardened serving: bounded admission (reject/drop_oldest),
machine-readable submit rejections, per-request deadlines (mid-stream and
in-queue), fair-share slot preemption with token-exact requeue (dense +
hybrid x weight forms x spec), NaN-logit quarantine, the degradation
ladder (spec -> plain, kernel -> fallback), deterministic fault injection
recovery for every fault class, and the run_all watchdog.

Weight-only quantization (``act_bits=None``) for every parity assertion:
per-row dynamic activation scales differ between a request's original
admission and its re-admission at a grown (prompt + committed) length, so
exact preemption parity — like bucketed-admission parity — is a
weight-only property (see the engine docstring's moe/act-quant caveat).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.serving.engine import ServingEngine, generate
from repro.serving.resilience import (FaultPlan, SubmitRejected,
                                      WatchdogExpired)

W3 = dataclasses.replace(W3A8, act_bits=None)


@pytest.fixture(scope="module", autouse=True)
def _drop_compile_caches():
    """This module compiles hundreds of engine graphs (the preemption
    matrix alone builds 8 engines x requeue buckets x solo refs); release
    the compiled executables when it finishes so the whole-suite process
    doesn't exhaust JIT code memory in later modules."""
    yield
    jax.clear_caches()

ARCH_FOR = {"dense": "qwen2-1.5b", "hybrid": "zamba2-1.2b"}

# distinct prompts spanning both small admission buckets, so requeue after
# preemption crosses bucket boundaries as the effective prompt grows
PROMPTS = [
    [1, 2, 3],
    [7, 8, 9, 10, 11],
    [20, 21, 22, 23, 24, 25, 26, 27, 28],
    [30, 31, 32, 33],
]


def _setup(family="dense", form="w"):
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    if form == "w":
        return cfg, params, FLOAT
    export = {"q": quant_dense.export_levels,
              "qp": quant_dense.export_container}[form]
    return cfg, export(params, W3), W3


def _ref(params, cfg, policy, prompt, max_new):
    out = generate(params, jnp.asarray([prompt], jnp.int32), cfg,
                   policy=policy, max_new_tokens=max_new, dtype=jnp.float32)
    return [int(t) for t in np.asarray(out[0, len(prompt):])]


# --- bounded admission -------------------------------------------------------

def test_submit_rejected_reason_codes():
    """Every submit() validation failure is a SubmitRejected with a
    machine-readable reason — and still a ValueError, so legacy callers
    keep working. The engine stays usable after each rejection."""
    cfg, params, policy = _setup()
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=16,
                        dtype=jnp.float32)
    cases = [
        (dict(prompt=[], max_new=4), "empty_prompt"),
        (dict(prompt=[1, 2], max_new=0), "bad_max_new"),
        (dict(prompt=list(range(1, 20)), max_new=4), "too_long"),
        (dict(prompt=[1, 2], max_new=4, deadline_ticks=0), "bad_deadline"),
    ]
    for kw, reason in cases:
        with pytest.raises(SubmitRejected) as ei:
            eng.submit(**kw)
        assert ei.value.reason == reason
        assert isinstance(ei.value, ValueError)
    assert eng.queue == []                    # nothing half-enqueued
    eng.submit([1, 2], max_new=3)
    done = eng.run_all()
    assert len(done) == 1 and done[0].status == "ok"


def test_bounded_admission_reject():
    """queue_limit with the reject policy: excess submissions return a
    falsy SubmitOutcome with reason 'queue_full' instead of growing the
    queue; accepted requests are unaffected and complete."""
    cfg, params, policy = _setup()
    eng = ServingEngine(params, cfg, policy=policy, slots=1, max_len=16,
                        dtype=jnp.float32, queue_limit=2)
    outs = [eng.submit([1, 2, 3], max_new=3) for _ in range(4)]
    assert [bool(o) for o in outs] == [True, True, False, False]
    assert outs[0].accepted and outs[0].uid == 1 and outs[0].reason is None
    assert not outs[2].accepted and outs[2].uid is None
    assert outs[2].reason == "queue_full"
    assert eng.shed_count == 2 and eng.queue_depth == 2
    done = eng.run_all()
    assert len(done) == 2 and all(r.status == "ok" for r in done)
    # the outcome IS the uid for accepted requests (legacy dict-key use)
    assert sorted(r.uid for r in done) == [int(outs[0]), int(outs[1])]


def test_bounded_admission_drop_oldest():
    """drop_oldest: the new request is admitted, the oldest QUEUED request
    is evicted — reported in the outcome's shed tuple and drained with
    status 'shed' and no output."""
    cfg, params, policy = _setup()
    eng = ServingEngine(params, cfg, policy=policy, slots=1, max_len=16,
                        dtype=jnp.float32, queue_limit=1,
                        shed_policy="drop_oldest")
    u1 = eng.submit([1, 2, 3], max_new=3)     # queued
    u2 = eng.submit([4, 5, 6], max_new=3)     # evicts u1
    assert u2.accepted and u2.shed == (int(u1),)
    assert eng.shed_count == 1 and eng.queue_depth == 1
    done = eng.run_all()
    by_uid = {r.uid: r for r in done}
    assert by_uid[int(u1)].status == "shed" and by_uid[int(u1)].out == []
    assert by_uid[int(u2)].status == "ok" and len(by_uid[int(u2)].out) == 3


# --- deadlines ---------------------------------------------------------------

def test_deadline_cancels_midstream():
    """A resident request past its deadline is cancelled mid-stream: the
    slot frees (the next request gets it), partial output is returned with
    status 'deadline'."""
    cfg, params, policy = _setup()
    ref = _ref(params, cfg, policy, [1, 2, 3], 12)
    eng = ServingEngine(params, cfg, policy=policy, slots=1, max_len=32,
                        dtype=jnp.float32)
    u1 = eng.submit([1, 2, 3], max_new=12, deadline_ticks=4)
    u2 = eng.submit([4, 5, 6], max_new=3)
    done = eng.run_all()
    by_uid = {r.uid: r for r in done}
    hit = by_uid[int(u1)]
    assert hit.status == "deadline"
    assert 0 < len(hit.out) < 12
    assert hit.out == ref[:len(hit.out)]      # partial stream, not garbage
    assert by_uid[int(u2)].status == "ok" and len(by_uid[int(u2)].out) == 3
    assert eng.deadline_miss_count == 1


def test_deadline_expires_in_queue():
    """default_deadline applies to every request; one stuck behind a long
    resident request expires WHILE QUEUED (never holds a slot) and drains
    with empty output."""
    cfg, params, policy = _setup()
    eng = ServingEngine(params, cfg, policy=policy, slots=1, max_len=32,
                        dtype=jnp.float32, default_deadline=2)
    u1 = eng.submit([1, 2, 3], max_new=6, deadline_ticks=50)  # long resident
    u2 = eng.submit([4, 5, 6], max_new=3)     # default deadline, queued
    done = eng.run_all()
    by_uid = {r.uid: r for r in done}
    assert by_uid[int(u1)].status == "ok"
    assert by_uid[int(u2)].status == "deadline"
    assert by_uid[int(u2)].out == [] and by_uid[int(u2)].ticks == 0
    assert eng.deadline_miss_count == 1


# --- preemption / requeue parity ---------------------------------------------

@pytest.mark.parametrize("spec_k", [0, 2])
@pytest.mark.parametrize("form", ["w", "qp"])
@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_preemption_parity(family, form, spec_k):
    """Forced preemption of EVERY request (fair-share budget of one tick
    while waiters exist), staggered admission, and drain() interleaved at
    every step: each requeued request's final stream is token-identical to
    its solo ``generate`` run — nothing lost, nothing duplicated across
    preempt/requeue/drain boundaries. Composes with speculative decoding
    (spec_k=2) and both weight forms."""
    cfg, params, policy = _setup(family, form)
    refs = {tuple(p): _ref(params, cfg, policy, p, 10) for p in PROMPTS}
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, preempt_after=1,
                        spec_k=spec_k, max_ticks=200)
    uid_to_prompt = {}
    for p in PROMPTS[:2]:                     # first wave fills both slots
        uid_to_prompt[eng.submit(p, max_new=10)] = tuple(p)
    eng.step()
    for p in PROMPTS[2:]:                     # waiters force preemption
        uid_to_prompt[eng.submit(p, max_new=10)] = tuple(p)
    done = []
    for _ in range(200):                      # drain at EVERY step boundary
        if not (eng.queue or eng._occupied()):
            break
        eng.step()
        done.extend(eng.drain())
    done.extend(eng.drain())
    assert len(done) == len(PROMPTS) and all(r.status == "ok" for r in done)
    for r in done:
        assert r.out == refs[uid_to_prompt[r.uid]], \
            (family, form, spec_k, uid_to_prompt[r.uid], r.out)
    # every request was actually preempted at least once — the parity
    # claim is about the requeue path, so it must have been exercised
    assert all(r.preemptions >= 1 for r in done), \
        [(r.uid, r.preemptions) for r in done]
    assert eng.preempt_count == sum(r.preemptions for r in done)


def test_preemption_with_early_eos():
    """EOS mid-stream while preemption churns: truncation lands exactly
    where the solo run's does, and freed-by-EOS slots are reobserved (the
    _sync-in-_spin_up path) rather than deadlocking the queue."""
    cfg, params, policy = _setup()
    full = _ref(params, cfg, policy, PROMPTS[0], 8)
    idx = next(i for i in range(1, len(full)) if full[i] not in full[:i])
    eos = full[idx]
    refs = {tuple(p): None for p in PROMPTS}
    for p in PROMPTS:
        r = _ref(params, cfg, policy, p, 8)
        refs[tuple(p)] = r[:r.index(eos) + 1] if eos in r else r
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, preempt_after=1, eos_id=eos,
                        max_ticks=200)
    uid_to_prompt = {eng.submit(p, max_new=8): tuple(p) for p in PROMPTS}
    done = eng.run_all()
    assert len(done) == len(PROMPTS)
    for r in done:
        assert r.out == refs[uid_to_prompt[r.uid]], \
            (uid_to_prompt[r.uid], r.out, refs[uid_to_prompt[r.uid]])


# --- health quarantine + degradation ladder ----------------------------------

@pytest.mark.parametrize("spec_k", [0, 2])
def test_nan_quarantine(spec_k):
    """An injected NaN in one slot's logits quarantines THAT request
    (status 'poisoned', partial prefix output, slot zeroed and reusable)
    while its neighbor finishes token-exact — in both tick modes."""
    cfg, params, policy = _setup()
    ref = _ref(params, cfg, policy, [4, 5, 6], 6)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, spec_k=spec_k,
                        fault_plan=FaultPlan(nan_logits=[(1, 0)]))
    u_bad = eng.submit([1, 2, 3], max_new=6)   # lands in slot 0
    u_ok = eng.submit([4, 5, 6], max_new=6)
    done = eng.run_all()
    by_uid = {r.uid: r for r in done}
    bad, ok = by_uid[int(u_bad)], by_uid[int(u_ok)]
    assert bad.status == "poisoned" and len(bad.out) < 6
    assert ok.status == "ok" and ok.out == ref
    assert eng.poisoned_count == 1
    # the quarantined slot was zeroed: a new request reuses it cleanly
    u3 = eng.submit([4, 5, 6], max_new=6)
    done2 = eng.run_all()
    assert len(done2) == 1 and done2[0].uid == int(u3)
    assert done2[0].status == "ok" and done2[0].out == ref


def test_tick_failure_degrades_spec_to_plain():
    """An injected tick failure on a speculative engine walks the first
    ladder step — the drafter is abandoned mid-run, the plain tick takes
    over, and the output stream is unaffected (spec is exact)."""
    cfg, params, policy = _setup()
    ref = _ref(params, cfg, policy, [1, 2, 3], 7)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, spec_k=2,
                        fault_plan=FaultPlan(fail_ticks=[1]))
    eng.submit([1, 2, 3], max_new=7)
    done = eng.run_all()
    assert done[0].status == "ok" and done[0].out == ref
    assert (1, "spec->plain") in eng.fallback_events
    assert not eng._spec and eng.spec_k == 0


def test_tick_failure_degrades_kernel_to_fallback():
    """On a non-speculative engine the ladder's second step rebuilds the
    dequant/ref graphs; the run completes token-exact with the event
    recorded."""
    cfg, params, policy = _setup(form="qp")
    ref = _ref(params, cfg, policy, [1, 2, 3], 6)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32,
                        fault_plan=FaultPlan(fail_ticks=[1]))
    eng.submit([1, 2, 3], max_new=6)
    done = eng.run_all()
    assert done[0].status == "ok" and done[0].out == ref
    assert (1, "kernel->fallback") in eng.fallback_events
    assert eng.matmul_mode == "dequant" and eng.attn_mode == "ref"


def test_tick_failure_transient_retry_without_degrade():
    """degrade=False: an injected (one-shot, i.e. transient) fault earns a
    same-graph retry instead of a ladder step; the retry succeeds and the
    run is token-exact."""
    cfg, params, policy = _setup()
    ref = _ref(params, cfg, policy, [1, 2, 3], 5)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, degrade=False,
                        fault_plan=FaultPlan(fail_ticks=[0, 2]))
    eng.submit([1, 2, 3], max_new=5)
    done = eng.run_all()
    assert done[0].status == "ok" and done[0].out == ref
    assert eng.fallback_events == [(0, "retry"), (2, "retry")]


def test_admission_delay_recovery():
    """Injected admission stalls defer the queued request without touching
    the resident one; admission resumes after the stall window and every
    request completes normally."""
    cfg, params, policy = _setup()
    ref1 = _ref(params, cfg, policy, [1, 2, 3], 4)
    ref2 = _ref(params, cfg, policy, [4, 5, 6], 4)
    eng = ServingEngine(params, cfg, policy=policy, slots=1, max_len=32,
                        dtype=jnp.float32, max_ticks=100,
                        fault_plan=FaultPlan(delay_admission=[1, 2]))
    u1 = eng.submit([1, 2, 3], max_new=4)
    u2 = eng.submit([4, 5, 6], max_new=4)
    done = eng.run_all()
    by_uid = {r.uid: r for r in done}
    assert by_uid[int(u1)].out == ref1 and by_uid[int(u2)].out == ref2
    assert all(r.status == "ok" for r in done)


# --- watchdog ----------------------------------------------------------------

def test_watchdog_raises_with_diagnostics():
    """A wedged engine (admission stalled forever) trips the run_all
    watchdog: WatchdogExpired carries a diagnostic dump naming the stuck
    queue, and work finished BEFORE the wedge stays drainable."""
    cfg, params, policy = _setup()
    eng = ServingEngine(params, cfg, policy=policy, slots=1, max_len=32,
                        dtype=jnp.float32,
                        fault_plan=FaultPlan(delay_admission=range(2, 10_000)))
    u1 = eng.submit([1, 2, 3], max_new=3)     # admitted at tick 0, finishes
    eng.submit([4, 5, 6], max_new=3)          # stuck behind the stall
    with pytest.raises(WatchdogExpired) as ei:
        eng.run_all(max_ticks=12)
    diag = ei.value.diagnostics
    assert diag["queue_depth"] == 1 and not diag["active_slots"]
    assert "shed_count" in diag and "fallback_events" in diag
    drained = eng.drain()
    assert [r.uid for r in drained] == [int(u1)]
    assert drained[0].status == "ok" and len(drained[0].out) == 3


def test_watchdog_constructor_default():
    """max_ticks set at construction applies to every run_all (the serve
    launcher path)."""
    cfg, params, policy = _setup()
    eng = ServingEngine(params, cfg, policy=policy, slots=1, max_len=32,
                        dtype=jnp.float32, max_ticks=2,
                        fault_plan=FaultPlan(delay_admission=range(10_000)))
    eng.submit([1, 2, 3], max_new=3)
    with pytest.raises(WatchdogExpired):
        eng.run_all()


# --- fault-plan determinism + chaos smoke ------------------------------------

def test_fault_plan_random_deterministic():
    """Same seed -> identical plan (the CI chaos generator must be
    reproducible); plans are immutable value objects."""
    a = FaultPlan.random(7, ticks=200, slots=4)
    b = FaultPlan.random(7, ticks=200, slots=4)
    assert a == b and not a.empty
    assert a != FaultPlan.random(8, ticks=200, slots=4)
    assert FaultPlan().empty
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.fail_ticks = frozenset()


def test_chaos_smoke_completes():
    """Seeded chaos (NaNs + tick failures + admission stalls) over an
    overloaded engine with deadlines and preemption: the run always
    terminates under the watchdog and every submitted request drains with
    a terminal status."""
    cfg, params, policy = _setup()
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, queue_limit=4,
                        shed_policy="drop_oldest", default_deadline=30,
                        preempt_after=2, spec_k=2, max_ticks=300,
                        fault_plan=FaultPlan.random(3, ticks=60, slots=2))
    outs = [eng.submit(PROMPTS[i % len(PROMPTS)], max_new=6)
            for i in range(8)]
    done = eng.run_all()
    assert len(done) == sum(1 for o in outs if o.accepted)
    assert all(r.status in ("ok", "deadline", "shed", "poisoned")
               for r in done)
    assert any(r.status == "ok" for r in done)
