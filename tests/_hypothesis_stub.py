"""Fallback shims for when ``hypothesis`` is not installed (optional dev dep,
see requirements-dev.txt).

Property-based tests decorated with the stub ``given`` are skipped with a
clear reason; plain unit tests in the same module still run. Usage:

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_stub import given, settings, st
"""
import pytest

_SKIP = pytest.mark.skip(
    reason="hypothesis not installed (pip install -r requirements-dev.txt); "
           "property-based cases skipped")


def given(*_args, **_kwargs):
    return lambda fn: _SKIP(fn)


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _Strategies:
    """Accepts any strategy-construction call at collection time."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _Strategies()
