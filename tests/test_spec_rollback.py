"""``rollback_cache`` — the speculative-rejection primitive — across
dense/hybrid x plain/int8-KV x sliding-window ring: wiped suffixes are
exactly un-written (values AND per-token scales), entries below the rewind
point are untouched, zero-distance/out-of-range rewinds are identities, and
decoding after a partial rollback continues exactly like a stream that
never speculated. The ``ssm`` family must refuse the whole spec surface."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.precision import FLOAT
from repro.models import api as model_api
from repro.models import get_model

ARCH_FOR = {"dense": "qwen2-1.5b", "ssm": "mamba2-2.7b",
            "hybrid": "zamba2-1.2b"}


def _setup(family, sliding=0):
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    if sliding:
        cfg = dataclasses.replace(cfg, sliding_window=sliding)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prefill_verify(cfg, params, quant, max_len=20, t=3):
    mod = get_model(cfg)
    toks = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    kw = {"quantize_cache": True} if quant else {}
    # per-row lengths: the slot-major shape the engine serves (and keeps
    # the rollback identity checks exact — rollback returns per-row len)
    _, cache = mod.prefill(params, {"tokens": toks}, cfg, policy=FLOAT,
                           dtype=jnp.float32, max_len=max_len,
                           lengths=jnp.asarray([4, 4]), **kw)
    vtoks = jnp.asarray([[9 + i for i in range(t)]] * 2, jnp.int32)
    _, vcache, traj = mod.verify_step(params, cache, vtoks, cfg,
                                      policy=FLOAT, dtype=jnp.float32)
    return mod, cache, vcache, traj


def _kv(cfg, cache):
    return cache["kv"] if cfg.family == "hybrid" else cache


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


@pytest.mark.parametrize("family", ["dense", "hybrid"])
@pytest.mark.parametrize("quant", [False, True])
def test_rollback_wipes_suffix_keeps_prefix(family, quant):
    cfg, params = _setup(family)
    mod, cache, vcache, traj = _prefill_verify(cfg, params, quant)
    base = jnp.broadcast_to(cache["len"], (2,)).astype(jnp.int32)
    rb = mod.rollback_cache(vcache, jnp.arange(2), base + 1, traj)
    names = ("k", "v") + (("k_scale", "v_scale") if quant else ())
    for name in names:
        a = np.asarray(_kv(cfg, rb)[name])
        b = np.asarray(_kv(cfg, vcache)[name])
        # entries at positions < base+1 (kept) are byte-identical ...
        assert np.array_equal(a[:, :, :5], b[:, :, :5]), name
        # ... and the rejected band [base+1, base+3) is zeroed — including
        # the int8 scale arrays, so cache and scales stay consistent
        assert not a[:, :, 5:7].any(), name
    assert list(np.asarray(rb["len"])) == [5, 5]
    if family == "hybrid":
        # state after 1 accepted token == snapshot 1 of the trajectory
        want = jax.tree_util.tree_map(lambda x: x[1], traj["groups"])
        assert _tree_equal(rb["groups"], want)


@pytest.mark.parametrize("family", ["dense", "hybrid"])
@pytest.mark.parametrize("quant", [False, True])
def test_zero_and_oob_rewind_are_identity(family, quant):
    cfg, params = _setup(family)
    mod, cache, vcache, traj = _prefill_verify(cfg, params, quant)
    cur = jnp.broadcast_to(vcache["len"], (2,)).astype(jnp.int32)
    # zero-distance rewind: new_lens == current lengths
    same = mod.rollback_cache(vcache, jnp.arange(2), cur, traj)
    assert _tree_equal(same, vcache)
    # out-of-range slot entries are dropped (nothing rewinds)
    oob = mod.rollback_cache(vcache, jnp.asarray([7, 9]),
                             jnp.zeros((2,), jnp.int32), traj)
    assert _tree_equal(oob, vcache)
    # rewinding "forward" (new_len > current) clamps to identity
    fwd = mod.rollback_cache(vcache, jnp.arange(2), cur + 3, traj)
    assert _tree_equal(fwd, vcache)


@pytest.mark.parametrize("family", ["dense", "hybrid"])
@pytest.mark.parametrize("quant", [False, True])
def test_decode_after_rollback_matches_unspeculated(family, quant):
    """The functional contract: accept j of the verified tokens, roll back,
    decode one more — logits match a stream that decoded the j tokens
    sequentially and never saw the rejected suffix."""
    cfg, params = _setup(family)
    mod, cache, vcache, traj = _prefill_verify(cfg, params, quant)
    base = jnp.broadcast_to(cache["len"], (2,)).astype(jnp.int32)
    nxt = jnp.asarray([[30], [30]], jnp.int32)
    for j in (1, 2):
        rb = mod.rollback_cache(vcache, jnp.arange(2), base + j, traj)
        seq = cache
        for t in range(j):
            _, seq = mod.decode_step(params, seq,
                                     jnp.asarray([[9 + t]] * 2, jnp.int32),
                                     cfg, policy=FLOAT, dtype=jnp.float32)
        la, _ = mod.decode_step(params, rb, nxt, cfg, policy=FLOAT,
                                dtype=jnp.float32)
        lb, _ = mod.decode_step(params, seq, nxt, cfg, policy=FLOAT,
                                dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=2e-5, rtol=0)


@pytest.mark.parametrize("quant", [False, True])
def test_rollback_swa_ring(quant):
    """Sliding-window arch: the cache is a position-mod-window ring. Within
    the engine's no-wrap regime (max_len <= window) rollback must wipe the
    correct ring band and keep decode-after-rollback exact."""
    cfg, params = _setup("dense", sliding=24)
    assert get_model(cfg).cache_len_for(cfg, 20) == 20   # ring layout, no wrap
    mod, cache, vcache, traj = _prefill_verify(cfg, params, quant,
                                               max_len=20)
    base = jnp.broadcast_to(cache["len"], (2,)).astype(jnp.int32)
    rb = mod.rollback_cache(vcache, jnp.arange(2), base + 1, traj)
    names = ("k", "v") + (("k_scale", "v_scale") if quant else ())
    for name in names:
        a = np.asarray(rb[name])
        assert np.array_equal(a[:, :, :5], np.asarray(vcache[name])[:, :, :5])
        assert not a[:, :, 5:7].any(), name
    la, _ = mod.decode_step(params, rb, jnp.asarray([[30]] * 2, jnp.int32),
                            cfg, policy=FLOAT, dtype=jnp.float32)
    seq = cache
    _, seq = mod.decode_step(params, seq, jnp.asarray([[9]] * 2, jnp.int32),
                             cfg, policy=FLOAT, dtype=jnp.float32)
    lb, _ = mod.decode_step(params, seq, jnp.asarray([[30]] * 2, jnp.int32),
                            cfg, policy=FLOAT, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=2e-5,
                               rtol=0)


def test_api_dispatch_and_ssm_rejection():
    """models.api routes the spec primitives; ssm refuses all of them."""
    cfg, params = _setup("dense")
    mod = get_model(cfg)
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    _, cache = mod.prefill(params, {"tokens": toks}, cfg, policy=FLOAT,
                           dtype=jnp.float32, max_len=16)
    _, vcache, traj = model_api.verify_step(params, cache,
                                            jnp.asarray([[5, 6]], jnp.int32),
                                            cfg, policy=FLOAT,
                                            dtype=jnp.float32)
    rb = model_api.rollback_cache(cfg, vcache, jnp.arange(1),
                                  jnp.asarray([4]), traj)
    assert int(rb["len"][0]) == 4
    assert model_api.spec_state_snapshot(cfg, cache) is None

    scfg, sparams = _setup("ssm")
    state = model_api.init_cache(scfg, 1, 16, jnp.float32)
    with pytest.raises(ValueError, match="ssm"):
        model_api.verify_step(sparams, state, toks[:, :2], scfg,
                              policy=FLOAT)
    with pytest.raises(ValueError, match="rewound|rewind"):
        model_api.rollback_cache(scfg, state, jnp.arange(1),
                                 jnp.asarray([1]))
    with pytest.raises(ValueError, match="ssm"):
        model_api.spec_state_snapshot(scfg, state)


def test_draft_of_derives_qp_drafter():
    """Any checkpoint yields a qp drafter (no second training run); the
    half-depth variant slices the stacked layer axis and stays runnable."""
    from repro.core import quant_dense
    cfg, params = _setup("dense")
    dcfg, dparams = model_api.draft_of(cfg, params)
    assert dcfg == cfg
    assert quant_dense.is_serve_form(dparams)
    # already-exported trees pass through un-re-exported
    dcfg2, again = model_api.draft_of(cfg, dparams)
    assert again is dparams and dcfg2 == cfg
    # half depth: layer stack sliced, config follows, model still decodes
    hcfg, hparams = model_api.draft_of(cfg, params, depth_fraction=0.5)
    assert hcfg.num_layers == cfg.num_layers // 2
    lg, cache = get_model(hcfg).prefill(
        hparams, {"tokens": jnp.asarray([[1, 2, 3]], jnp.int32)}, hcfg,
        policy=FLOAT, dtype=jnp.float32, max_len=8)
    assert lg.shape == (1, 1, cfg.vocab_size)
    with pytest.raises(ValueError, match="depth_fraction"):
        model_api.draft_of(cfg, params, depth_fraction=0.0)


def test_draft_of_half_depth_hybrid():
    cfg, params = _setup("hybrid")
    n_groups = cfg.num_layers // cfg.attn_every
    hcfg, hparams = model_api.draft_of(cfg, params, depth_fraction=0.5)
    kept = max(1, n_groups // 2)
    assert (hcfg.num_layers
            == kept * cfg.attn_every + cfg.num_layers % cfg.attn_every)
    lg, _ = get_model(hcfg).prefill(
        hparams, {"tokens": jnp.asarray([[1, 2, 3]], jnp.int32)}, hcfg,
        policy=FLOAT, dtype=jnp.float32, max_len=8)
    assert lg.shape == (1, 1, cfg.vocab_size)
