"""Elastic restart: a checkpoint written under one mesh restores onto a
DIFFERENT mesh shape (node-failure / re-scaling story). Runs in a subprocess
with forced host devices (main pytest process stays single-device)."""
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro import checkpoint as ckpt_lib
from repro.configs import get_config, reduced, ShapeConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import compat_make_mesh
from repro.models import get_model

cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=64, vocab=128)
mod = get_model(cfg)
params = mod.init(jax.random.PRNGKey(0), cfg)

mesh_a = compat_make_mesh((2, 4), ("data", "model"))
mesh_b = compat_make_mesh((4, 2), ("data", "model"))

# place params on mesh A, checkpoint, restore onto mesh B
specs_a = shd.param_specs(cfg, params, mesh_a)
sh_a = shd.tree_shardings(mesh_a, specs_a)
params_a = jax.tree_util.tree_map(
    lambda x, s: jax.device_put(x, s) if s is not None else x, params, sh_a)

with tempfile.TemporaryDirectory() as td:
    ckpt_lib.save(td, 1, {"params": params_a})
    specs_b = shd.param_specs(cfg, params, mesh_b)
    sh_b = shd.tree_shardings(mesh_b, specs_b)
    tree, meta = ckpt_lib.restore(td, shardings={"params": sh_b})

# values identical, new sharding applied
flat_old = jax.tree_util.tree_leaves(params)
flat_new = jax.tree_util.tree_leaves(tree["params"])
for a, b in zip(flat_old, flat_new):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)

# forward pass works under the new mesh
with mesh_b:
    logits, _ = mod.forward(
        jax.tree_util.tree_map(jnp.asarray, tree["params"]),
        {"tokens": jnp.zeros((4, 8), jnp.int32)}, cfg,
        policy=__import__("repro.core.precision", fromlist=["FLOAT"]).FLOAT,
        dtype=jnp.float32)
assert not bool(jnp.any(jnp.isnan(logits)))
print("ELASTIC_OK")
"""


def test_elastic_remesh_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, cwd=".", timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
