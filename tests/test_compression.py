"""Gradient compression: error feedback correctness + convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (dequantize_grad,
                                           make_grad_compressor,
                                           quantize_grad)


def test_quantize_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, s = quantize_grad(g)
    err = np.abs(np.asarray(dequantize_grad(q, s) - g))
    assert err.max() <= float(s) / 2 + 1e-7


def test_error_feedback_accumulates_to_truth():
    """Sum of compressed grads + final residual == sum of true grads."""
    tf = make_grad_compressor()
    state = {}
    true_sum = jnp.zeros((64,))
    comp_sum = jnp.zeros((64,))
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (64,)) * 0.1}
        true_sum = true_sum + g["w"]
        gq, state = tf(g, state)
        comp_sum = comp_sum + gq["w"]
    resid = state["ef"]["w"]
    np.testing.assert_allclose(np.asarray(comp_sum + resid),
                               np.asarray(true_sum), atol=1e-4)


def test_compressor_in_train_step():
    from repro.configs import TrainConfig, get_config, reduced
    from repro.core.precision import FLOAT
    from repro.data.synthetic import lm_batch
    from repro.models import get_model
    from repro.training.loop import make_train_step

    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=32, vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=30, warmup_steps=3)
    step, init_state = make_train_step(cfg, tcfg, FLOAT, dtype=jnp.float32,
                                       grad_transform=make_grad_compressor())
    state = init_state(params)
    state["ef"] = None   # lazily created
    losses = []
    for i in range(25):
        batch = lm_batch(jnp.asarray(0), jnp.asarray(i), batch=8, seq=16,
                         vocab=cfg.vocab_size)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    assert "ef" in state and state["ef"] is not None
