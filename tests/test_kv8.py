"""int8 KV cache (beyond-paper, §Perf H-kv8): decode matches bf16-cache decode
within quantization tolerance; scales factor exactly through attention."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.precision import FLOAT
from repro.models import transformer
from repro.models.transformer import _quantize_kv

B, S, P = 2, 20, 16


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16)) * 3
    q, s = _quantize_kv(x)
    back = q.astype(jnp.float32) * s[..., None, None]
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(s)) / 2 + 1e-5


def test_kv8_decode_close_to_bf16():
    cfg = reduced(get_config("qwen3-32b"))
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    logits_f, cache_f = transformer.prefill(
        params, {"tokens": toks[:, :P]}, cfg, policy=FLOAT,
        dtype=jnp.float32, max_len=S)
    logits_q, cache_q = transformer.prefill(
        params, {"tokens": toks[:, :P]}, cfg, policy=FLOAT,
        dtype=jnp.float32, max_len=S, quantize_cache=True)
    assert cache_q["k"].dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_f),
                               atol=1e-4)   # prefill logits don't read cache

    for t in range(P, S):
        logits_f, cache_f = transformer.decode_step(
            params, cache_f, toks[:, t:t + 1], cfg, policy=FLOAT,
            dtype=jnp.float32)
        logits_q, cache_q = transformer.decode_step(
            params, cache_q, toks[:, t:t + 1], cfg, policy=FLOAT,
            dtype=jnp.float32)
        # int8 cache error stays small through multiple steps
        err = float(jnp.max(jnp.abs(logits_q - logits_f)))
        denom = float(jnp.max(jnp.abs(logits_f))) + 1e-6
        assert err / denom < 0.05, (t, err, denom)


def test_kv8_cache_is_half_the_bytes():
    cfg = reduced(get_config("qwen3-32b"))
    c_f = transformer.init_cache(cfg, 4, 64)
    c_q = transformer.init_cache(cfg, 4, 64, quantized=True)
    nb = lambda c: sum(x.size * x.dtype.itemsize
                       for x in jax.tree_util.tree_leaves(c))
    assert nb(c_q) < nb(c_f) * 0.55
