"""int8 KV cache (beyond-paper, §Perf H-kv8): decode matches bf16-cache decode
within quantization tolerance; scales factor exactly through attention —
for the transformer family AND hybrid, through the sliding-window ring, and
through the launch-layer kv8 cache templates."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.precision import FLOAT
from repro.models import hybrid, transformer
from repro.models.transformer import _quantize_kv

B, S, P = 2, 20, 16


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16)) * 3
    q, s = _quantize_kv(x)
    back = q.astype(jnp.float32) * s[..., None, None]
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(s)) / 2 + 1e-5


def test_kv8_decode_close_to_bf16():
    cfg = reduced(get_config("qwen3-32b"))
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    logits_f, cache_f = transformer.prefill(
        params, {"tokens": toks[:, :P]}, cfg, policy=FLOAT,
        dtype=jnp.float32, max_len=S)
    logits_q, cache_q = transformer.prefill(
        params, {"tokens": toks[:, :P]}, cfg, policy=FLOAT,
        dtype=jnp.float32, max_len=S, quantize_cache=True)
    assert cache_q["k"].dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_f),
                               atol=1e-4)   # prefill logits don't read cache

    for t in range(P, S):
        logits_f, cache_f = transformer.decode_step(
            params, cache_f, toks[:, t:t + 1], cfg, policy=FLOAT,
            dtype=jnp.float32)
        logits_q, cache_q = transformer.decode_step(
            params, cache_q, toks[:, t:t + 1], cfg, policy=FLOAT,
            dtype=jnp.float32)
        # int8 cache error stays small through multiple steps
        err = float(jnp.max(jnp.abs(logits_q - logits_f)))
        denom = float(jnp.max(jnp.abs(logits_f))) + 1e-6
        assert err / denom < 0.05, (t, err, denom)


def test_kv8_cache_is_half_the_bytes():
    cfg = reduced(get_config("qwen3-32b"))
    c_f = transformer.init_cache(cfg, 4, 64)
    c_q = transformer.init_cache(cfg, 4, 64, quantized=True)
    nb = lambda c: sum(x.size * x.dtype.itemsize
                       for x in jax.tree_util.tree_leaves(c))
    assert nb(c_q) < nb(c_f) * 0.55


# --- hybrid family -----------------------------------------------------------------


def test_kv8_hybrid_decode_close_to_bf16():
    """Hybrid int8-KV (per shared-attention application) tracks the float
    cache through multiple decode steps."""
    cfg = reduced(get_config("zamba2-1.2b"), layers=4)   # 2 groups of 2
    params = hybrid.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    logits_f, st_f = hybrid.prefill(params, {"tokens": toks[:, :P]}, cfg,
                                    policy=FLOAT, dtype=jnp.float32, max_len=S)
    logits_q, st_q = hybrid.prefill(params, {"tokens": toks[:, :P]}, cfg,
                                    policy=FLOAT, dtype=jnp.float32, max_len=S,
                                    quantize_cache=True)
    assert st_q["kv"]["k"].dtype == jnp.int8
    assert st_q["kv"]["k_scale"].shape == st_q["kv"]["k"].shape[:3]
    np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_f),
                               atol=1e-4)   # prefill logits don't read cache

    for t in range(P, S):
        logits_f, st_f = hybrid.decode_step(params, st_f, toks[:, t:t + 1],
                                            cfg, policy=FLOAT,
                                            dtype=jnp.float32)
        logits_q, st_q = hybrid.decode_step(params, st_q, toks[:, t:t + 1],
                                            cfg, policy=FLOAT,
                                            dtype=jnp.float32)
        err = float(jnp.max(jnp.abs(logits_q - logits_f)))
        denom = float(jnp.max(jnp.abs(logits_f))) + 1e-6
        assert err / denom < 0.05, (t, err, denom)


def test_kv8_hybrid_cache_is_half_the_kv_bytes():
    cfg = reduced(get_config("zamba2-1.2b"), layers=4)
    c_f = hybrid.init_cache(cfg, 4, 64)
    c_q = hybrid.init_cache(cfg, 4, 64, quantized=True)
    nb = lambda kv: sum(x.size * x.dtype.itemsize
                        for x in jax.tree_util.tree_leaves(kv))
    # mamba states are untouched; the KV part (entries + scales) halves
    assert nb(c_q["kv"]) < nb(c_f["kv"]) * 0.6


# --- sliding-window ring x int8 ----------------------------------------------------


def test_kv8_swa_ring_scales_rotate_with_slots():
    """Decode past the window: the int8 ring overwrites value AND scale at
    slot pos % window, so each slot's scale always matches its token."""
    cfg = reduced(get_config("mixtral-8x22b"))
    cfg = dataclasses.replace(cfg, sliding_window=8, num_experts=0,
                              family="dense")
    params = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

    logits_f, cache_f = transformer.prefill(
        params, {"tokens": toks[:, :P]}, cfg, policy=FLOAT,
        dtype=jnp.float32, max_len=S)
    logits_q, cache_q = transformer.prefill(
        params, {"tokens": toks[:, :P]}, cfg, policy=FLOAT,
        dtype=jnp.float32, max_len=S, quantize_cache=True)
    cs = cache_q["k"].shape[2]
    assert cs == 8                                   # ring bounded by window
    for t in range(P, S):
        logits_f, cache_f = transformer.decode_step(
            params, cache_f, toks[:, t:t + 1], cfg, policy=FLOAT,
            dtype=jnp.float32)
        prev_ks = cache_q["k_scale"]
        logits_q, cache_q = transformer.decode_step(
            params, cache_q, toks[:, t:t + 1], cfg, policy=FLOAT,
            dtype=jnp.float32)
        # exactly ONE ring slot's scale was rewritten this step: t % cs
        changed = np.nonzero(np.any(np.asarray(cache_q["k_scale"])
                                    != np.asarray(prev_ks), axis=(0, 1)))[0]
        assert list(changed) == [t % cs], (t, changed)
        err = float(jnp.max(jnp.abs(logits_q - logits_f)))
        denom = float(jnp.max(jnp.abs(logits_f))) + 1e-6
        assert err / denom < 0.05, (t, err, denom)


def test_kv8_ring_masking_parity_kernel_vs_ref():
    """Per-row cache_len masking over an int8 ring cache: the fused kernel
    agrees with the kernel-package oracle AND the einsum path when rows sit
    at different fill levels of the same ring."""
    from repro.kernels.attn_decode.ops import attn_decode
    from repro.kernels.attn_decode.ref import attn_decode_ref
    from repro.models.attention import decode_attention

    b, s, h, kv, d = 4, 24, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, s, kv, d))
    vc = jax.random.normal(ks[2], (b, s, kv, d))
    kq, ksc = _quantize_kv(kc)
    vq, vsc = _quantize_kv(vc)
    lens = jnp.asarray([3, 24, 11, 17], jnp.int32)   # mixed ring fill
    out = attn_decode(q, kq, vq, lens, ksc, vsc, bm=2, bs=8, interpret=True)
    ref = attn_decode_ref(q, kq, vq, lens, ksc, vsc)
    ein = decode_attention(q, kq, vq, lens, ksc, vsc, mode="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ein), atol=2e-5)


# --- launch-layer kv8 templates (launch/steps.py) ----------------------------------


def _decode_shape():
    from repro.configs.base import ShapeConfig
    return ShapeConfig("dec", 16, 2, "decode")


def test_steps_kv8_hybrid_template_is_quantized():
    """Regression: hybrid kv8 used to silently fall through to the bf16
    cache; now the decode cell template carries the int8 KV form."""
    from repro.launch import steps

    cfg = reduced(get_config("zamba2-1.2b"), layers=4)
    t = steps._cache_template(cfg, _decode_shape(), kv8=True)
    assert t["kv"]["k"].dtype == jnp.int8
    assert "k_scale" in t["kv"] and "v_scale" in t["kv"]


def test_steps_kv8_ssm_warns_instead_of_silent_downgrade():
    from repro.launch import steps

    cfg = reduced(get_config("mamba2-2.7b"), layers=2)
    with pytest.warns(UserWarning, match="KV cache"):
        t = steps._cache_template(cfg, _decode_shape(), kv8=True)
    assert "kv" not in t                              # plain ssm state

    # non-kv8 path stays warning-free for every family
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        steps._cache_template(cfg, _decode_shape(), kv8=False)
