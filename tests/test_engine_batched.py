"""Batched continuous-batching engine: token parity with single-request
``generate`` for every family x weight form, slot isolation under mid-stream
admission, and the core scaling invariant — one jitted ``decode_step`` per
tick regardless of how many slots are active (the paper's weight-streaming
amortization depends on exactly this)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.models import api as model_api
from repro.serving.engine import ServingEngine, generate

# weight-only W3 for the form sweep; full W3A8 (dynamic 8-bit act scales) is
# exercised separately below — scales are per-ROW since the kernel-dispatch
# PR, so act quant no longer couples batch rows
W3 = dataclasses.replace(W3A8, act_bits=None)

ARCH_FOR = {"dense": "qwen2-1.5b", "ssm": "mamba2-2.7b",
            "hybrid": "zamba2-1.2b"}
PROMPT = [1, 2, 3, 4]
# == the smallest admission bucket (engine._MIN_BUCKET): batched prefill
# adds no intra-row padding, so a row's dynamic act absmax sees exactly the
# tokens the solo run sees (padding POSITIONS inside a row would enter its
# per-row scale; padding ROWS never do)
PROMPT_BUCKET = [1, 2, 3, 4, 5, 6, 7, 8]


def _setup(family, form):
    layers = 4 if family == "hybrid" else 2    # hybrid: 2 groups of 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    if form == "w":
        return cfg, params, FLOAT
    if form == "q":
        return cfg, quant_dense.export_levels(params, W3), W3
    return cfg, quant_dense.export_container(params, W3), W3


def _ref_tokens(params, cfg, policy, max_new):
    out = generate(params, jnp.asarray([PROMPT], jnp.int32), cfg,
                   policy=policy, max_new_tokens=max_new, dtype=jnp.float32)
    return [int(t) for t in np.asarray(out[0, len(PROMPT):])]


@pytest.mark.parametrize("form", ["w", "q", "qp"])
@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_engine_matches_generate(family, form):
    """Every slot's tokens == single-request generate, all families/forms."""
    cfg, params, policy = _setup(family, form)
    ref = _ref_tokens(params, cfg, policy, max_new=5)
    eng = ServingEngine(params, cfg, policy=policy, slots=3, max_len=32,
                        dtype=jnp.float32)
    for _ in range(4):                      # 4 requests through 3 slots
        eng.submit(PROMPT, max_new=5)
    done = eng.run_all()
    assert len(done) == 4 and all(r.done for r in done)
    for r in done:
        assert r.out == ref, (family, form, r.out, ref)


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_engine_matches_generate_act_bits(family):
    """Full W3A8 (dynamic 8-bit activation scales): per-ROW scales keep
    slots independent, so engine tokens == solo generate even under act
    quant — including a late wave admitted mid-decode next to busy slots.
    Prompts sit exactly on the admission bucket (see PROMPT_BUCKET)."""
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    sp = quant_dense.export_container(params, W3A8)
    out = generate(sp, jnp.asarray([PROMPT_BUCKET], jnp.int32), cfg,
                   policy=W3A8, max_new_tokens=5, dtype=jnp.float32)
    ref = [int(t) for t in np.asarray(out[0, len(PROMPT_BUCKET):])]
    eng = ServingEngine(sp, cfg, policy=W3A8, slots=3, max_len=32,
                        dtype=jnp.float32)
    for _ in range(3):
        eng.submit(PROMPT_BUCKET, max_new=5)
    eng.step(); eng.step()                  # first wave mid-decode...
    eng.submit(PROMPT_BUCKET, max_new=5)    # ...second wave rides along
    done = eng.run_all()
    assert len(done) == 4 and all(r.done for r in done)
    for r in done:
        assert r.out == ref, (family, r.out, ref)


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_mid_stream_admission_does_not_perturb_active_slots(family):
    """A request admitted while another is decoding must not change the
    active slot's continuation (slot-major rows are independent)."""
    cfg, params, policy = _setup(family, "w")
    ref_a = _ref_tokens(params, cfg, policy, max_new=6)
    eng = ServingEngine(params, cfg, policy=policy, slots=4, max_len=32,
                        dtype=jnp.float32)
    eng.submit(PROMPT, max_new=6)
    eng.step(); eng.step()                  # request A mid-decode
    eng.submit([7, 8, 9, 10, 11], max_new=4)   # different prompt + length
    done = eng.run_all()
    a = next(r for r in done if r.uid == 1)
    b = next(r for r in done if r.uid == 2)
    assert a.out == ref_a, (a.out, ref_a)
    # B itself matches its own solo run
    ref_b = generate(params, jnp.asarray([[7, 8, 9, 10, 11]], jnp.int32), cfg,
                     policy=policy, max_new_tokens=4, dtype=jnp.float32)
    assert b.out == [int(t) for t in np.asarray(ref_b[0, 5:])]


def test_one_decode_call_per_tick():
    """An engine tick issues exactly ONE decode_step regardless of the
    number of active slots — no per-slot Python loop. Counted at the family
    module so any fallback to per-request decoding would show up."""
    from repro.models import transformer

    cfg, params, policy = _setup("dense", "w")
    calls = {"n": 0}
    orig = transformer.decode_step

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    transformer.decode_step = counting
    try:
        with jax.disable_jit():
            eng = ServingEngine(params, cfg, policy=policy, slots=4,
                                max_len=16, dtype=jnp.float32)
            for _ in range(4):              # all four slots active at once
                eng.submit(PROMPT, max_new=3)
            ticks = 0
            while eng.queue or eng._occupied():
                eng.step()
                ticks += 1
            eng.drain()
        assert calls["n"] == ticks == eng.decode_calls
        assert ticks < 4 * 3                # batched: NOT requests x tokens
    finally:
        transformer.decode_step = orig

    # under jit the tick is traced once and replayed: still one decode_step
    # trace total, while the engine advances many ticks
    calls["n"] = 0
    transformer.decode_step = counting
    try:
        eng = ServingEngine(params, cfg, policy=policy, slots=4, max_len=16,
                            dtype=jnp.float32)
        for _ in range(4):
            eng.submit(PROMPT, max_new=3)
        done = eng.run_all()
        assert len(done) == 4
        assert eng.decode_calls >= 2        # several ticks ran...
        assert calls["n"] <= 2              # ...but only the trace called in
    finally:
        transformer.decode_step = orig


def test_shared_cache_allocated_once_per_slot_lens():
    """The engine owns ONE slot-major cache with per-slot length counters."""
    cfg, params, policy = _setup("dense", "w")
    eng = ServingEngine(params, cfg, policy=policy, slots=4, max_len=16,
                        dtype=jnp.float32)
    assert eng.cache["len"].shape == (4,)
    assert eng.cache["k"].shape[1] == 4     # (L, slots, S, KV, D)
    eng.submit(PROMPT, max_new=2)
    eng.submit(PROMPT, max_new=4)
    eng.step()
    lens = np.asarray(eng.cache["len"])
    assert lens[0] == lens[1] == len(PROMPT) + 1   # both slots advanced
    assert lens[2] == lens[3] == 0                 # free slots untouched


def test_insert_prefill_roundtrip_ssm():
    """insert_prefill drops a batch=1 prefill state into the right slot and
    leaves other slots bit-identical."""
    cfg, params, policy = _setup("ssm", "w")
    mod = get_model(cfg)
    shared = model_api.init_cache(cfg, 3, 16, jnp.float32, per_slot_len=True)
    before = jax.tree_util.tree_map(np.asarray, shared)
    _, src = mod.prefill(params, {"tokens": jnp.asarray([PROMPT], jnp.int32)},
                         cfg, policy=policy, dtype=jnp.float32, max_len=16)
    out = mod.insert_prefill(shared, jnp.asarray(1, jnp.int32), src)
    assert int(out["len"][1]) == len(PROMPT)
    assert int(out["len"][0]) == 0 and int(out["len"][2]) == 0
    # untouched slots identical
    for leaf_b, leaf_a in zip(jax.tree_util.tree_leaves(before["layers"]),
                              jax.tree_util.tree_leaves(out["layers"])):
        np.testing.assert_array_equal(leaf_b[:, 0], np.asarray(leaf_a)[:, 0])
        np.testing.assert_array_equal(leaf_b[:, 2], np.asarray(leaf_a)[:, 2])


@pytest.mark.parametrize("drain_every", [1, 4])
def test_eos_frees_slot_for_queue(drain_every):
    """EOS termination mid-budget frees the slot; queued work lands in it.
    drain_every > 1 exercises the admission-internal sync, which must not
    lose the finished request from run_all()'s results."""
    cfg, params, policy = _setup("dense", "w")
    ref = _ref_tokens(params, cfg, policy, max_new=8)
    # EOS = a token whose FIRST occurrence is mid-stream (not the prefill
    # sample), so termination exercises the decode path
    idx = next(i for i in range(1, len(ref)) if ref[i] not in ref[:i])
    eng = ServingEngine(params, cfg, policy=policy, slots=1, max_len=32,
                        dtype=jnp.float32, eos_id=ref[idx],
                        drain_every=drain_every)
    eng.submit(PROMPT, max_new=8)
    eng.submit(PROMPT, max_new=8)
    done = eng.run_all()
    assert len(done) == 2, [r.uid for r in done]
    for r in done:
        assert r.out == ref[:idx + 1], (r.out, ref, idx)
