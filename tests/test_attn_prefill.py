"""Blocked online-softmax prefill/verify attention (kernels/attn_prefill,
interpret mode): parity against its pure-jnp oracle (ref.py) and the
production chunked/einsum paths across blocking edge cases (T/S not
divisible by the block sizes, mixed row lengths, single-row buckets, bf16 +
int8 KV, SWA windows), the empty-row guard regression
(verify_attention/chunked_attention), the jaxpr-asserted absence of the
quadratic (T, S) score tensor in kernel-mode prefill and verify graphs, and
engine-level token parity of kernel-mode prefill+verify vs ref under
staggered admission."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import check_no_quadratic_scores
from repro.configs import get_config, reduced
from repro.core.precision import FLOAT, W3A8
from repro.kernels.attn_prefill.ops import attn_prefill
from repro.kernels.attn_prefill.ref import attn_prefill_ref
from repro.models import api as model_api
from repro.models import get_model, transformer
from repro.models.attention import (chunked_attention, prefill_attention,
                                    sliding_window_attention,
                                    verify_attention)
from repro.models.transformer import _quantize_kv
from repro.serving.engine import ServingEngine, generate

W3 = dataclasses.replace(W3A8, act_bits=None)


def _case(seed, b, t, s, h, kv, d, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, t, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    return q, k, v


def _oracle(q, k, v, hi, lo=None, k_scale=None, v_scale=None):
    """attn_prefill_ref through the same GQA/pre-scale plumbing as ops.py."""
    b, t, h, d = q.shape
    kv = k.shape[2]
    qg = (q * (d ** -0.5)).reshape(b, t, kv, h // kv, d)
    lo = jnp.zeros((b, t), jnp.int32) if lo is None else lo
    return attn_prefill_ref(qg, k, v, lo, hi, k_scale,
                            v_scale).reshape(b, t, h, d)


def _prefill_hi(lens, t):
    pos = jnp.arange(t, dtype=jnp.int32)
    return jnp.minimum(pos[None, :] + 1, jnp.asarray(lens, jnp.int32)[:, None])


# --- kernel vs oracle: blocking edge cases ----------------------------------------

@pytest.mark.parametrize("h,kv", [(8, 2), (4, 4), (4, 1)])
@pytest.mark.parametrize("bt,bs", [(16, 32), (8, 24), (7, 13), (128, 128)])
def test_kernel_matches_oracle_blocking(h, kv, bt, bs):
    """Mixed per-row lengths (incl. 1 and full) under the bucketed-prefill
    rule; bt/bs sweep covers T and S not divisible by the block sizes."""
    b, t, d = 3, 50, 16
    q, k, v = _case(0, b, t, t, h, kv, d)
    hi = _prefill_hi([50, 17, 1], t)
    out = attn_prefill(q, k, v, hi, bt=bt, bs=bs, interpret=True)
    ref = _oracle(q, k, v, hi)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    # every REAL query position also matches the production chunked path
    # (causal-only masking: j <= t < len already implies j < len there)
    chunked = chunked_attention(q, k, v, causal=True, chunk=32)
    for row, ln in enumerate([50, 17, 1]):
        np.testing.assert_allclose(np.asarray(out[row, :ln]),
                                   np.asarray(chunked[row, :ln]), atol=2e-5)


def test_kernel_single_row_bucket():
    """B=1 admission bucket, T=S=33 not divisible by either block size."""
    q, k, v = _case(1, 1, 33, 33, 4, 2, 8)
    hi = _prefill_hi([33], 33)
    out = attn_prefill(q, k, v, hi, bt=8, bs=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_oracle(q, k, v, hi)),
                               atol=2e-6)


def test_kernel_bf16():
    q, k, v = _case(2, 2, 40, 40, 8, 2, 16, jnp.bfloat16)
    hi = _prefill_hi([40, 23], 40)
    out = attn_prefill(q, k, v, hi, bt=16, bs=16, interpret=True)
    ref = _oracle(q, k, v, hi)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


def test_kernel_int8_kv_with_scales():
    """int8 K/V + per-token scales read directly: the fused dequant epilogue
    must factor the scales exactly where the ref einsum does."""
    b, t = 3, 41
    q, k, v = _case(3, b, t, t, 8, 2, 16)
    kq, ksc = _quantize_kv(k)
    vq, vsc = _quantize_kv(v)
    hi = _prefill_hi([41, 9, 28], t)
    out = attn_prefill(q, kq, vq, hi, k_scale=ksc, v_scale=vsc,
                       bt=16, bs=16, interpret=True)
    ref = _oracle(q, kq, vq, hi, k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    # and the int8 path stays close to the float attention it encodes
    full = _oracle(q, k, v, hi)
    assert float(jnp.max(jnp.abs(out - full))) < 0.1


def test_kernel_swa_window():
    """lo bounds = sliding window: kernel == sliding_window_attention at
    full length (no row padding), == oracle with the lo/hi mask."""
    b, t, w = 2, 40, 8
    q, k, v = _case(4, b, t, t, 4, 2, 8)
    pos = jnp.arange(t, dtype=jnp.int32)
    hi = jnp.broadcast_to(pos[None, :] + 1, (b, t))
    lo = jnp.broadcast_to(jnp.maximum(pos - (w - 1), 0)[None], (b, t))
    out = attn_prefill(q, k, v, hi, lo=lo, bt=16, bs=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_oracle(q, k, v, hi, lo=lo)),
                               atol=2e-6)
    swa = sliding_window_attention(q, k, v, window=w, chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(swa), atol=2e-5)


# --- dispatch entry points --------------------------------------------------------

def test_prefill_attention_dispatch():
    """prefill_attention(mode=...) kernel path == ref path at every real
    query position, for plain and SWA masking."""
    b, t = 3, 36
    q, k, v = _case(5, b, t, t, 4, 2, 8)
    lens = jnp.asarray([36, 12, 5], jnp.int32)
    out_k = prefill_attention(q, k, v, lengths=lens, mode="kernel",
                              interpret=True)
    out_r = prefill_attention(q, k, v, lengths=lens, mode="ref", chunk=16)
    for row, ln in enumerate([36, 12, 5]):
        np.testing.assert_allclose(np.asarray(out_k[row, :ln]),
                                   np.asarray(out_r[row, :ln]), atol=2e-5)
    sw_k = prefill_attention(q, k, v, window=8, mode="kernel", interpret=True)
    sw_r = prefill_attention(q, k, v, window=8, mode="ref", chunk=16)
    np.testing.assert_allclose(np.asarray(sw_k), np.asarray(sw_r), atol=2e-5)


def test_verify_attention_dispatch():
    """verify_attention(mode='kernel') — the T-row specialization over the
    live cache — matches the guarded-einsum ref, float and int8 cache."""
    b, t, s = 3, 3, 50
    q, _, _ = _case(6, b, t, s, 8, 2, 16)
    _, kc, vc = _case(7, b, t, s, 8, 2, 16)
    valid = jnp.asarray([[5, 6, 7], [1, 2, 3], [48, 49, 50]], jnp.int32)
    out_k = verify_attention(q, kc, vc, valid, mode="kernel", interpret=True)
    out_r = verify_attention(q, kc, vc, valid, mode="ref")
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5)
    kq, ksc = _quantize_kv(kc)
    vq, vsc = _quantize_kv(vc)
    out_k8 = verify_attention(q, kq, vq, valid, ksc, vsc, mode="kernel",
                              interpret=True)
    out_r8 = verify_attention(q, kq, vq, valid, ksc, vsc, mode="ref")
    np.testing.assert_allclose(np.asarray(out_k8), np.asarray(out_r8),
                               atol=2e-5)


# --- empty-row guard regression ---------------------------------------------------

def test_verify_attention_empty_row_guard():
    """A zero-valid-length row (all-false mask — engine padding) must yield
    zeros from BOTH paths, never NaN or the uniform average over v."""
    b, t, s = 2, 3, 32
    q, kc, vc = _case(8, b, t, s, 4, 2, 8)
    valid = jnp.asarray([[0, 0, 0], [4, 5, 6]], jnp.int32)
    for mode in ("ref", "kernel"):
        out = verify_attention(q, kc, vc, valid, mode=mode, interpret=True)
        assert not np.any(np.isnan(np.asarray(out))), mode
        np.testing.assert_array_equal(np.asarray(out[0]), 0.0, err_msg=mode)
        assert float(jnp.max(jnp.abs(out[1]))) > 0, mode


def test_chunked_attention_empty_row_guard():
    """q_offset < 0 makes query 0's causal mask all-false across every
    chunk: the scan's online softmax must emit zeros for it, not the
    uniform v average (and never NaN)."""
    q, k, v = _case(9, 2, 4, 8, 4, 2, 8)
    out = chunked_attention(q, k, v, causal=True, chunk=4, q_offset=-1)
    assert not np.any(np.isnan(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out[:, 0]), 0.0)
    assert float(jnp.max(jnp.abs(out[:, 1:]))) > 0


# --- the tentpole invariant: no (T, S) score tensor in kernel-mode graphs ---------
# (the jaxpr walking lives in repro.analysis now — the shared pass keeps
# this test's exact strictness: any float tensor with trailing (T, S) dims
# outside pallas_call, rank >= 2, or a missing pallas_call, is a violation)

def _graph_cfg():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=32, vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_prefill_graph_has_no_quadratic_score_tensor():
    """Jitted kernel-mode prefill contains NO float (..., T, T) score tensor
    outside the pallas_call — the quadratic-HBM intermediate is gone."""
    cfg, params = _graph_cfg()
    t = 48
    toks = jnp.zeros((2, t), jnp.int32)
    lens = jnp.asarray([48, 20], jnp.int32)

    def run(mode):
        fn = lambda tk: transformer.prefill(
            params, {"tokens": tk}, cfg, policy=FLOAT, dtype=jnp.float32,
            lengths=lens, max_len=64, attn_mode=mode)
        return jax.make_jaxpr(fn)(toks)

    viols = check_no_quadratic_scores(run("kernel"), t, t,
                                      require_pallas=True)
    assert not viols, "; ".join(str(v) for v in viols)
    # detector sanity: the ref chunked path DOES build (B, KV, G, T, chunk)
    # tiles with chunk == T here, so the same check must trip on it
    assert check_no_quadratic_scores(run("ref"), t, t), \
        "detector lost its ref signal"


def test_verify_graph_has_no_score_tensor():
    """Jitted kernel-mode verify_step contains NO float (..., T, S) score
    tensor outside the pallas_call (T = spec_k+1, S = the decode cache)."""
    cfg, params = _graph_cfg()
    t, s = 3, 40
    cache = model_api.init_cache(cfg, 2, s, jnp.float32, per_slot_len=True)
    cache["len"] = jnp.asarray([7, 11], jnp.int32)
    toks = jnp.zeros((2, t), jnp.int32)

    def run(mode):
        fn = lambda c, tk: transformer.verify_step(
            params, c, tk, cfg, policy=FLOAT, dtype=jnp.float32,
            attn_mode=mode)
        return jax.make_jaxpr(fn)(cache, toks)

    viols = check_no_quadratic_scores(run("kernel"), t, s,
                                      require_pallas=True)
    assert not viols, "; ".join(str(v) for v in viols)
    assert check_no_quadratic_scores(run("ref"), t, s), \
        "detector lost its ref signal"


# --- engine-level token parity ----------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_engine_kernel_prefill_verify_matches_ref(family):
    """attn_mode='kernel' (blocked Pallas prefill + verify + fused decode,
    interpret mode on CPU) is token-identical to attn_mode='ref' through
    the speculative engine under staggered bucketed admission."""
    arch = "zamba2-1.2b" if family == "hybrid" else "qwen2-1.5b"
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(arch), layers=layers, d_model=32, vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    prompts = {4: [1, 2, 3, 4], 9: [5, 4, 3, 2, 1, 2, 3, 4, 5]}

    def solo(prompt, mode):
        out = generate(params, jnp.asarray([prompt], jnp.int32), cfg,
                       policy=FLOAT, max_new_tokens=4, dtype=jnp.float32,
                       attn_mode=mode, spec_k=2)
        return [int(x) for x in np.asarray(out[0, len(prompt):])]

    ref = {n: solo(p, "ref") for n, p in prompts.items()}
    assert {n: solo(p, "kernel") for n, p in prompts.items()} == ref

    eng = ServingEngine(params, cfg, policy=FLOAT, slots=3, max_len=32,
                        dtype=jnp.float32, attn_mode="kernel", spec_k=2)
    for n in (4, 9, 4):                     # two buckets, batched admission
        eng.submit(prompts[n], max_new=4)
    eng.step(); eng.step()                  # first wave mid-decode...
    eng.submit(prompts[9], max_new=4)       # ...late wave rides along
    done = eng.run_all()
    assert len(done) == 4 and all(r.done for r in done)
    for r in done:
        assert r.out == ref[len(r.prompt)], (family, r.out)
