"""Length-bucketed batched admission: N same-bucket requests enter through
ONE jitted prefill + ONE jitted multi-slot admit, jit re-traces are bounded
by the bucket count (not the number of distinct prompt lengths), and
mixed-length batched prefill is token-exact vs single-request ``generate``
in all three families — including under staggered mid-decode admission.

Weight-only policies (``act_bits=None``) throughout: dynamic activation
scales are per-ROW (slots are independent), but a padded prefill row's
absmax still sees its padding positions, so exact parity under act quant
needs bucket-aligned prompts — mixed off-bucket lengths are this file's
whole point, hence weight-only here (the act-quant parity case lives in
test_engine_batched.py::test_engine_matches_generate_act_bits).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.serving.engine import ServingEngine, generate

W3 = dataclasses.replace(W3A8, act_bits=None)

ARCH_FOR = {"dense": "qwen2-1.5b", "ssm": "mamba2-2.7b",
            "hybrid": "zamba2-1.2b"}

# heterogeneous lengths spanning two buckets (<=8 and 9..16)
PROMPTS = [
    [1, 2, 3],
    [7, 8, 9, 10, 11],
    [20, 21, 22, 23, 24, 25, 26, 27, 28],
    [30, 31, 32, 33],
    [40, 41, 42, 43, 44, 45, 46, 47, 48, 49, 50, 51],
]


def _setup(family, form):
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    if form == "w":
        return cfg, params, FLOAT
    if form == "q":
        return cfg, quant_dense.export_levels(params, W3), W3
    return cfg, quant_dense.export_container(params, W3), W3


def _ref(params, cfg, policy, prompt, max_new):
    out = generate(params, jnp.asarray([prompt], jnp.int32), cfg,
                   policy=policy, max_new_tokens=max_new, dtype=jnp.float32)
    return [int(t) for t in np.asarray(out[0, len(prompt):])]


@pytest.mark.parametrize("form", ["w", "qp"])
@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid"])
def test_mixed_length_batched_admission_matches_generate(family, form):
    """Heterogeneous prompt lengths admitted in bucketed batches, with a
    staggered mid-decode submission wave: every request's tokens equal its
    own solo ``generate`` run (slot rows are independent)."""
    cfg, params, policy = _setup(family, form)
    refs = {tuple(p): _ref(params, cfg, policy, p, 5) for p in PROMPTS}
    eng = ServingEngine(params, cfg, policy=policy, slots=3, max_len=32,
                        dtype=jnp.float32)
    uid_to_prompt = {}
    for p in PROMPTS[:3]:                        # first wave fills all slots
        uid_to_prompt[eng.submit(p, max_new=5)] = tuple(p)
    eng.step(); eng.step()                       # decode in flight...
    for p in PROMPTS[3:]:                        # ...second wave queues up
        uid_to_prompt[eng.submit(p, max_new=5)] = tuple(p)
    done = eng.run_all()
    assert len(done) == len(PROMPTS) and all(r.done for r in done)
    for r in done:
        assert r.out == refs[uid_to_prompt[r.uid]], \
            (family, form, uid_to_prompt[r.uid], r.out)
    # two length buckets were in play -> at most two prefill compilations
    assert eng._prefill_fn._cache_size() <= 2


def test_same_bucket_admission_is_single_prefill_and_admit():
    """Admitting N same-bucket queued requests issues exactly ONE jitted
    prefill call and ONE jitted admit (the tentpole invariant)."""
    cfg, params, policy = _setup("dense", "w")
    eng = ServingEngine(params, cfg, policy=policy, slots=4, max_len=32,
                        dtype=jnp.float32)
    for ln in (3, 4, 5, 6):                      # all in the <=8 bucket
        eng.submit(list(range(1, ln + 1)), max_new=3)
    eng.step()
    assert eng.prefill_calls == 1
    assert eng._prefill_fn._cache_size() == 1
    assert eng._admit_many_fn._cache_size() == 1
    eng.run_all()
    # a later same-bucket wave: one more batched call, NO new compilation
    eng.submit([9, 9, 9], max_new=3)
    eng.submit([5, 5], max_new=3)
    eng.step()
    assert eng.prefill_calls == 2
    assert eng._prefill_fn._cache_size() == 1


def test_retraces_bounded_by_bucket_count():
    """Ten distinct prompt lengths, two buckets: jit cache stays at two
    entries — O(#buckets), not O(#distinct lengths)."""
    cfg, params, policy = _setup("dense", "w")
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32)
    for ln in range(1, 11):                      # lengths 1..10
        eng.submit([1] * ln, max_new=2)
    done = eng.run_all()
    assert len(done) == 10
    assert eng._prefill_fn._cache_size() <= 2
    assert eng.prefill_calls >= 2                # several admission rounds...
    assert eng.decode_calls >= 1


def test_mixed_buckets_one_round_two_prefills():
    """A single spin-up with two buckets in the queue issues one batched
    prefill per bucket (not per request)."""
    cfg, params, policy = _setup("dense", "w")
    eng = ServingEngine(params, cfg, policy=policy, slots=4, max_len=32,
                        dtype=jnp.float32)
    eng.submit([1, 2, 3], max_new=2)             # bucket 8
    eng.submit([1] * 12, max_new=2)              # bucket 16
    eng.submit([4, 5], max_new=2)                # bucket 8 again
    eng.step()
    assert eng.prefill_calls == 2


def test_submit_rejects_empty_prompt():
    """A [] prompt must fail fast at submit() with ValueError, not crash
    deep inside prefill with a (1, 0) token array (regression). The raise
    is a SubmitRejected carrying a machine-readable reason code."""
    from repro.serving.resilience import SubmitRejected
    cfg, params, policy = _setup("dense", "w")
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=16,
                        dtype=jnp.float32)
    with pytest.raises(ValueError, match="at least one token") as ei:
        eng.submit([], max_new=4)
    assert isinstance(ei.value, SubmitRejected)
    assert ei.value.reason == "empty_prompt"
    assert eng.queue == []                       # nothing half-enqueued
    eng.submit([1, 2], max_new=4)                # engine still usable
    done = eng.run_all()
    assert len(done) == 1 and len(done[0].out) == 4
