"""Batched engine with an int8 KV cache (``kv_bits=8``) and the fused
decode-attention dispatch (``attn_mode``): token parity with single-request
``generate`` under staggered admission for the transformer family AND
hybrid (mirrors tests/test_engine_batched.py), halved cache bytes per slot,
and explicit rejection for the no-KV family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.models import api as model_api
from repro.serving.engine import ServingEngine, generate

W3 = dataclasses.replace(W3A8, act_bits=None)

ARCH_FOR = {"dense": "qwen2-1.5b", "ssm": "mamba2-2.7b",
            "hybrid": "zamba2-1.2b"}
PROMPT = [1, 2, 3, 4]


def _setup(family, form="w"):
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    if form == "w":
        return cfg, params, FLOAT
    return cfg, quant_dense.export_container(params, W3), W3


def _ref_tokens(params, cfg, policy, max_new, **kw):
    out = generate(params, jnp.asarray([PROMPT], jnp.int32), cfg,
                   policy=policy, max_new_tokens=max_new, dtype=jnp.float32,
                   **kw)
    return [int(t) for t in np.asarray(out[0, len(PROMPT):])]


@pytest.mark.parametrize("family", ["dense", "hybrid"])
@pytest.mark.parametrize("form", ["w", "qp"])
def test_engine_kv8_matches_generate_staggered(family, form):
    """kv_bits=8 engine tokens == kv_bits=8 solo generate — including a
    request admitted mid-decode next to busy slots (the int8 scatter and
    per-slot scales must stay row-independent)."""
    cfg, params, policy = _setup(family, form)
    ref = _ref_tokens(params, cfg, policy, max_new=5, kv_bits=8)
    eng = ServingEngine(params, cfg, policy=policy, slots=3, max_len=32,
                        dtype=jnp.float32, kv_bits=8)
    for _ in range(3):
        eng.submit(PROMPT, max_new=5)
    eng.step(); eng.step()                  # first wave mid-decode...
    eng.submit(PROMPT, max_new=5)           # ...late wave rides along
    done = eng.run_all()
    assert len(done) == 4 and all(r.done for r in done)
    for r in done:
        assert r.out == ref, (family, form, r.out, ref)


@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_engine_kv8_kernel_attn_matches_generate(family):
    """attn_mode='kernel' (fused Pallas decode attention, interpret mode on
    CPU) x kv_bits=8 through the batched engine == the same solo path."""
    cfg, params, policy = _setup(family)
    ref = _ref_tokens(params, cfg, policy, max_new=4, kv_bits=8,
                      attn_mode="kernel")
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, kv_bits=8, attn_mode="kernel")
    for _ in range(3):                      # 3 requests through 2 slots
        eng.submit(PROMPT, max_new=4)
    done = eng.run_all()
    assert len(done) == 3 and all(r.done for r in done)
    for r in done:
        assert r.out == ref, (family, r.out, ref)


@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_kernel_attn_matches_ref_attn_tokens(family):
    """attn_mode='kernel' is token-identical to attn_mode='ref' for solo
    generate AND the engine (bf16-class cache)."""
    cfg, params, policy = _setup(family)
    ref = _ref_tokens(params, cfg, policy, max_new=5, attn_mode="ref")
    ker = _ref_tokens(params, cfg, policy, max_new=5, attn_mode="kernel")
    assert ker == ref, (family, ker, ref)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, attn_mode="kernel")
    eng.submit(PROMPT, max_new=5)
    done = eng.run_all()
    assert done[0].out == ref, (family, done[0].out, ref)


@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_kv8_halves_engine_cache_bytes(family):
    cfg, params, policy = _setup(family)

    def nbytes(eng):
        leaves = (eng.cache["kv"] if family == "hybrid"
                  else {k: eng.cache[k] for k in ("k", "v")})
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(leaves))

    e16 = ServingEngine(params, cfg, policy=policy, slots=4, max_len=64)
    e8 = ServingEngine(params, cfg, policy=policy, slots=4, max_len=64,
                       kv_bits=8)
    # ~0.5 + the per-token fp32 scales, which only matter at this toy
    # head_dim (8B vs 2*KV*D entry bytes; negligible at production sizes)
    assert nbytes(e8) < nbytes(e16) * 0.6, (nbytes(e8), nbytes(e16))


def test_kv8_rejected_for_ssm():
    """No silent downgrade: a family without a KV cache must refuse
    kv_bits=8 loudly (engine AND the shared init_cache helper)."""
    cfg, params, policy = _setup("ssm")
    with pytest.raises(ValueError, match="ssm"):
        ServingEngine(params, cfg, policy=policy, slots=2, max_len=16,
                      kv_bits=8)
    with pytest.raises(ValueError, match="ssm"):
        model_api.init_cache(cfg, 2, 16, jnp.float32, kv_bits=8)
    with pytest.raises(ValueError):
        model_api.init_cache(cfg, 2, 16, jnp.float32, kv_bits=4)


def test_bad_attn_mode_rejected():
    cfg, params, policy = _setup("dense")
    with pytest.raises(ValueError, match="attn"):
        ServingEngine(params, cfg, policy=policy, slots=2, max_len=16,
                      attn_mode="einsum")
