"""Unit + property tests for the optimal uniform quantizer (paper step 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:     # optional dev dep; see requirements-dev.txt
    from _hypothesis_stub import given, settings, st

from repro.core import quantizer as qz

jax.config.update("jax_platform_name", "cpu")


class TestMaxLevel:
    def test_paper_levels(self):
        assert qz.max_level(3) == 3           # paper: -3..3
        assert qz.max_level(2) == 1           # ternary (ref [14])
        assert qz.max_level(8) == 127

    def test_rejects_1bit(self):
        with pytest.raises(ValueError):
            qz.max_level(1)


class TestOptimalDelta:
    def test_beats_naive_absmax(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 0.2
        spec = qz.QuantSpec(bits=3)
        mse_opt = float(qz.quantization_mse(w, spec))
        d_naive = jnp.max(jnp.abs(w)) / 3
        q = jnp.clip(jnp.round(w / d_naive), -3, 3)
        mse_naive = float(jnp.mean((w - q * d_naive) ** 2))
        assert mse_opt <= mse_naive + 1e-12

    def test_exact_on_grid(self):
        """Weights already on a 3-bit grid quantize losslessly."""
        delta = 0.37
        q_true = jnp.array([-3, -2, -1, 0, 1, 2, 3, 1, -1, 2], jnp.float32)
        w = q_true * delta
        spec = qz.QuantSpec(bits=3)
        q, d = qz.quantize(w, spec)
        np.testing.assert_allclose(
            np.asarray(qz.dequantize(q, d, spec)), np.asarray(w), rtol=1e-5)

    def test_per_channel(self):
        key = jax.random.PRNGKey(1)
        w = jax.random.normal(key, (64, 8)) * jnp.linspace(0.01, 1.0, 8)
        spec_pc = qz.QuantSpec(bits=3, per_channel=-1)
        spec_pt = qz.QuantSpec(bits=3)
        assert qz.optimal_uniform_delta(w, spec_pc).shape == (8,)
        assert float(qz.quantization_mse(w, spec_pc)) <= \
            float(qz.quantization_mse(w, spec_pt)) + 1e-12

    def test_all_zero_weights(self):
        w = jnp.zeros((128,))
        q, d = qz.quantize(w, qz.QuantSpec(bits=3))
        assert np.all(np.asarray(q) == 0)
        assert np.isfinite(float(d))

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 8), st.integers(0, 2**31 - 1), st.floats(0.01, 10.0))
    def test_levels_in_range_property(self, bits, seed, scale):
        w = jax.random.normal(jax.random.PRNGKey(seed), (257,)) * scale
        spec = qz.QuantSpec(bits=bits)
        q, d = qz.quantize(w, spec)
        m = qz.max_level(bits)
        assert int(jnp.max(q)) <= m and int(jnp.min(q)) >= -m
        assert float(d) > 0

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_idempotent_property(self, seed):
        """quantize(dequantize(quantize(w))) is a fixed point."""
        w = jax.random.normal(jax.random.PRNGKey(seed), (512,)) * 0.3
        spec = qz.QuantSpec(bits=3)
        q1, d1 = qz.quantize(w, spec)
        w1 = qz.dequantize(q1, d1, spec)
        q2, d2 = qz.quantize(w1, spec)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2))
        np.testing.assert_allclose(float(d1), float(d2), rtol=1e-4)
