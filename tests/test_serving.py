"""Serving engine + quantized-serve param forms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.serving.engine import ServingEngine, generate


def _setup():
    cfg = reduced(get_config("qwen2-1.5b"), layers=2, d_model=32, vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generate_greedy_deterministic():
    cfg, params = _setup()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 64)
    out1 = generate(params, prompts, cfg, policy=FLOAT, max_new_tokens=8)
    out2 = generate(params, prompts, cfg, policy=FLOAT, max_new_tokens=8)
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :6]), np.asarray(prompts))


def test_serve_forms_match_fake_quant():
    """Packed/levels inference == STE fake-quant forward (deployment parity,
    the paper's 'download the weights to the device' step)."""
    cfg, params = _setup()
    mod = get_model(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0, 64)
    lv = quant_dense.export_levels(params, W3A8)
    ct = quant_dense.export_container(params, W3A8)
    out_lv, _ = mod.forward(lv, {"tokens": toks}, cfg, policy=W3A8,
                            dtype=jnp.float32)
    out_ct, _ = mod.forward(ct, {"tokens": toks}, cfg, policy=W3A8,
                            dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out_lv), np.asarray(out_ct),
                               atol=1e-4)
    assert not bool(jnp.any(jnp.isnan(out_lv)))


def test_serving_engine_continuous_batching():
    cfg, params = _setup()
    eng = ServingEngine(params, cfg, policy=FLOAT, slots=2, max_len=32,
                        dtype=jnp.float32)
    for _ in range(5):
        eng.submit([1, 2, 3], max_new=4)
    done = eng.run_all()
    assert len(done) == 5
    assert all(len(r.out) == 4 for r in done)
    # same prompt => same greedy continuation regardless of slot scheduling
    outs = {tuple(r.out) for r in done}
    assert len(outs) == 1
