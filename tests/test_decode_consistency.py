"""Serving correctness: prefill + step-by-step decode must reproduce the
teacher-forced forward logits (MoE with no-drop capacity — capacity dropping
is non-causal by construction, see models/moe.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model

B, S, P = 2, 20, 16

# weight-only W3: exact decode parity (weights quantize identically in both
# passes). Full W3A8's DYNAMIC activation scales differ between a whole-
# sequence pass and a single-token pass (absmax over S tokens vs 1) — an
# inherent dynamic-act-quant serving skew, bounded below; production serving
# uses static calibrated scales.
W3_ONLY = dataclasses.replace(W3A8, act_bits=None)


def _cfg(arch):
    cfg = reduced(get_config(arch))
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # no dropping
    return cfg


def _run(arch, policy, atol):
    cfg = _cfg(arch)
    mod = get_model(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = mod.forward(params, {"tokens": toks}, cfg, policy=policy,
                          dtype=jnp.float32)
    logits, cache = mod.prefill(params, {"tokens": toks[:, :P]}, cfg,
                                policy=policy, dtype=jnp.float32, max_len=S)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, P - 1]), atol=atol)
    for t in range(P, S):
        logits, cache = mod.decode_step(params, cache, toks[:, t:t + 1], cfg,
                                        policy=policy, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=atol,
                                   err_msg=f"step {t}")


@pytest.mark.parametrize("arch", ["qwen3-32b", "qwen2.5-14b", "mixtral-8x22b",
                                  "phi3.5-moe-42b-a6.6b", "mamba2-2.7b",
                                  "zamba2-1.2b", "musicgen-large"])
@pytest.mark.parametrize("policy", [FLOAT, W3_ONLY], ids=["float", "w3"])
def test_decode_matches_teacher_forcing(arch, policy):
    _run(arch, policy, atol=2e-4)


def test_decode_w3a8_dynamic_act_skew_bounded():
    """Full W3A8 (dynamic 8-bit act scales): skew exists but stays small."""
    _run("qwen3-32b", W3A8, atol=0.15)


def test_swa_ring_buffer_wraps_correctly():
    """Decode far past the window: ring overwrites must stay correct."""
    cfg = _cfg("mixtral-8x22b")
    cfg = dataclasses.replace(cfg, sliding_window=8, num_experts=0,
                              family="dense")
    mod = get_model(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = mod.forward(params, {"tokens": toks}, cfg, policy=FLOAT,
                          dtype=jnp.float32)
    logits, cache = mod.prefill(params, {"tokens": toks[:, :P]}, cfg,
                                policy=FLOAT, dtype=jnp.float32, max_len=S)
    assert cache["k"].shape[2] == 8            # bounded by window
    for t in range(P, S):
        logits, cache = mod.decode_step(params, cache, toks[:, t:t + 1], cfg,
                                        policy=FLOAT, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-4,
                                   err_msg=f"step {t}")
