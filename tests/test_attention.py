"""Chunked / sliding-window / decode attention vs a naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention, decode_attention,
                                    sliding_window_attention)


def ref_attn(q, k, v, causal=True, window=0):
    b, lq, h, d = q.shape
    lkv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / d ** 0.5
    qp, kp = jnp.arange(lq)[:, None], jnp.arange(lkv)[None, :]
    mask = jnp.ones((lq, lkv), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


def _qkv(seed, b, l, h, kv, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, l, h, d)),
            jax.random.normal(ks[1], (b, l, kv, d)),
            jax.random.normal(ks[2], (b, l, kv, d)))


@pytest.mark.parametrize("l,chunk", [(64, 64), (64, 16), (70, 32), (5, 8)])
@pytest.mark.parametrize("h,kv", [(8, 8), (8, 2), (4, 1)])
def test_chunked_matches_ref(l, chunk, h, kv):
    q, k, v = _qkv(0, 2, l, h, kv, 16)
    out = chunked_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_attn(q, k, v)),
                               atol=2e-5)


@pytest.mark.parametrize("window,chunk", [(8, 16), (24, 16), (128, 32)])
def test_sliding_window_matches_ref(window, chunk):
    q, k, v = _qkv(1, 2, 70, 8, 2, 16)
    out = sliding_window_attention(q, k, v, window=window, chunk=chunk)
    ref = ref_attn(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_matches_last_position():
    b, l, h, kv, d = 2, 40, 8, 2, 16
    q, k, v = _qkv(2, b, l, h, kv, d)
    s = 64
    kc = jnp.zeros((b, s, kv, d)).at[:, :l].set(k)
    vc = jnp.zeros((b, s, kv, d)).at[:, :l].set(v)
    out = decode_attention(q[:, -1:], kc, vc, jnp.full((b,), l))
    ref = ref_attn(q, k, v)[:, -1:]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_ring_permutation_invariance():
    """Ring-buffer storage order must not change decode attention."""
    b, l, h, kv, d = 1, 16, 4, 4, 8
    q, k, v = _qkv(3, b, l, h, kv, d)
    out1 = decode_attention(q[:, -1:], k, v, jnp.full((b,), l))
    perm = jax.random.permutation(jax.random.PRNGKey(9), l)
    out2 = decode_attention(q[:, -1:], k[:, perm], v[:, perm],
                            jnp.full((b,), l))
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=2e-5)
