"""Speculative serving through the batched engine: T=0 token parity with
the non-speculative path for every rollback-capable family x weight form
under staggered admission, the one-jitted-call tick contract (trace-count
and jaxpr asserted — no per-draft-token host sync), budget/EOS exactness
with variable tokens per tick, per-request stats, and the loud rejections
(ssm, ring wrap, missing headroom)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import check_no_host_callback, retrace_report
from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.serving.engine import ServingEngine, generate

W3 = dataclasses.replace(W3A8, act_bits=None)

ARCH_FOR = {"dense": "qwen2-1.5b", "ssm": "mamba2-2.7b",
            "hybrid": "zamba2-1.2b"}
PROMPT = [1, 2, 3, 4]


def _setup(family, form="w"):
    layers = 4 if family == "hybrid" else 2
    cfg = reduced(get_config(ARCH_FOR[family]), layers=layers, d_model=32,
                  vocab=64)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    if form == "w":
        return cfg, params, FLOAT
    export = {"q": quant_dense.export_levels,
              "qp": quant_dense.export_container}[form]
    return cfg, export(params, W3), W3


def _ref_tokens(params, cfg, policy, max_new, **kw):
    out = generate(params, jnp.asarray([PROMPT], jnp.int32), cfg,
                   policy=policy, max_new_tokens=max_new, dtype=jnp.float32,
                   **kw)
    return [int(t) for t in np.asarray(out[0, len(PROMPT):])]


@pytest.mark.parametrize("family", ["dense", "hybrid"])
@pytest.mark.parametrize("form", ["w", "q", "qp"])
def test_spec_parity_greedy_staggered(family, form):
    """Spec engine output == NON-spec greedy output, with a request
    admitted mid-decode next to busy slots — rollback and per-slot
    acceptance must stay row-independent. The drafter is the derived qp
    export (api.draft_of default), i.e. a genuinely imperfect drafter:
    parity must hold through real rejections."""
    cfg, params, policy = _setup(family, form)
    ref = _ref_tokens(params, cfg, policy, max_new=7)
    eng = ServingEngine(params, cfg, policy=policy, slots=3, max_len=32,
                        dtype=jnp.float32, spec_k=3)
    for _ in range(3):
        eng.submit(PROMPT, max_new=7)
    eng.step(); eng.step()                  # first wave mid-decode...
    eng.submit(PROMPT, max_new=7)           # ...late wave rides along
    done = eng.run_all()
    assert len(done) == 4 and all(r.done for r in done)
    for r in done:
        assert r.out == ref, (family, form, r.out, ref)
    assert 0.0 <= eng.spec_accept_rate <= 1.0


def test_spec_tick_single_jitted_call_and_no_callbacks():
    """The whole draft(K+1 steps)->verify->accept->rollback tick is ONE
    jitted function: it compiles exactly once across a staggered run
    (trace count), and its jaxpr contains no host-callback primitives —
    there is nothing to sync per draft token."""
    cfg, params, policy = _setup("dense")
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, spec_k=3, draft_params=params)
    calls = {"n": 0}
    inner = eng._tick_fn

    def counting(*a, **k):
        calls["n"] += 1
        return inner(*a, **k)
    eng._tick_fn = counting

    for _ in range(3):                      # 3 requests through 2 slots
        eng.submit(PROMPT, max_new=6)
    done = eng.run_all()
    assert len(done) == 3
    assert calls["n"] == eng.decode_calls   # one jitted call per tick
    # ...compiled exactly once: the retrace budget comes from the analysis
    # registry (engine._jits / trace_counts), same surface the sweep
    # report uses — not a private counter on the jit object
    rep = retrace_report(eng, budgets={"tick": 1})
    assert rep["counts"]["tick"] == 1 and not rep["violations"], rep
    # self-draft => every draft accepted => 4 tokens per live tick: far
    # fewer target passes than tokens (the whole point)
    dec_toks = sum(len(r.out) - 1 for r in done)
    live_ticks = sum(r.ticks for r in done)
    assert dec_toks == 4 * (live_ticks - len(done)) + sum(
        r.accept_hist.get(n, 0) * n for r in done for n in r.accept_hist
        if n < 4), "self-draft ticks emit full windows except the last"
    assert eng.spec_accept_rate == 1.0
    # jaxpr of the tick: traceable end to end, no callback primitives
    # (the shared no_host_callback pass also rejects device_put/infeed)
    jaxpr = jax.make_jaxpr(eng._spec_tick)(
        eng.params, eng.draft_params, eng.cache, eng.draft_cache,
        eng._tokens, eng._active, eng._emitted, eng._budget,
        eng._poison0, jax.random.PRNGKey(0))
    assert not check_no_host_callback(jaxpr)


def test_spec_budget_exact_when_not_window_multiple():
    """max_new=5 with spec_k=3 (windows of up to 4): the last window must
    truncate to the remaining budget, not overshoot."""
    cfg, params, policy = _setup("dense")
    ref = _ref_tokens(params, cfg, policy, max_new=5)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, spec_k=3, draft_params=params)
    eng.submit(PROMPT, max_new=5)
    done = eng.run_all()
    assert done[0].out == ref and len(done[0].out) == 5


def test_spec_eos_mid_window():
    """An EOS inside an accepted window truncates the request exactly
    where the non-speculative EOS path would."""
    cfg, params, policy = _setup("dense")
    ref = _ref_tokens(params, cfg, policy, max_new=8)
    # the EOS must FIRST appear mid-stream (a token repeated from earlier
    # would truncate at its first occurrence, not the index we picked)
    idx = next(i for i in range(1, len(ref)) if ref[i] not in ref[:i])
    eos, want = ref[idx], ref[:idx + 1]
    for spec_k, draft in ((0, None), (3, params)):
        eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                            dtype=jnp.float32, eos_id=eos, spec_k=spec_k,
                            draft_params=draft)
        eng.submit(PROMPT, max_new=8)
        done = eng.run_all()
        assert done[0].out == want, (spec_k, done[0].out, want)


def test_spec_request_stats():
    """Drained requests carry ticks + the accept-length histogram; the
    histogram accounts for every decode-emitted token."""
    cfg, params, policy = _setup("dense")
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, spec_k=2)
    eng.submit(PROMPT, max_new=6)
    eng.submit(PROMPT, max_new=6)
    done = eng.run_all()
    for r in done:
        assert r.ticks >= 1
        assert sum(r.accept_hist.values()) == r.ticks
        assert sum(n * c for n, c in r.accept_hist.items()) == len(r.out) - 1
        assert all(1 <= n <= 3 for n in r.accept_hist)
    drafted = sum(r.ticks for r in done) * 2
    assert eng.spec_drafted == drafted
    assert 0 <= eng.spec_accepted <= drafted
    # non-spec engines keep the same stats surface ({1: ticks} histogram)
    eng0 = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                         dtype=jnp.float32)
    eng0.submit(PROMPT, max_new=4)
    r0 = eng0.run_all()[0]
    assert r0.accept_hist == {1: 3} and r0.ticks == 3
    assert eng0.spec_accept_rate == 0.0


def test_generate_spec_matches_generate(capsys):
    """generate(spec_k=) is token-identical to plain greedy generate for a
    multi-row batch (the jitted while_loop path)."""
    cfg, params, policy = _setup("hybrid")
    prompts = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    ref = generate(params, prompts, cfg, policy=policy, max_new_tokens=6,
                   dtype=jnp.float32)
    spec = generate(params, prompts, cfg, policy=policy, max_new_tokens=6,
                    dtype=jnp.float32, spec_k=2)
    assert np.array_equal(np.asarray(ref), np.asarray(spec))
    one = generate(params, prompts, cfg, policy=policy, max_new_tokens=1,
                   dtype=jnp.float32, spec_k=2)
    assert np.array_equal(np.asarray(one), np.asarray(ref[:, :4]))


def test_spec_rejections():
    """ssm target and drafter, ring-wrapping SWA, bad spec_k, vocab
    mismatch, and missing submit headroom all fail loudly."""
    scfg, sparams, spolicy = _setup("ssm")
    with pytest.raises(ValueError, match="ssm"):
        ServingEngine(sparams, scfg, policy=spolicy, slots=2, max_len=16,
                      spec_k=2)
    with pytest.raises(ValueError, match="ssm"):
        generate(sparams, jnp.asarray([PROMPT], jnp.int32), scfg,
                 policy=spolicy, max_new_tokens=4, spec_k=2)

    cfg, params, policy = _setup("dense")
    swa = dataclasses.replace(cfg, sliding_window=8)
    with pytest.raises(ValueError, match="sliding"):
        ServingEngine(params, swa, policy=policy, slots=2, max_len=16,
                      spec_k=2)
    with pytest.raises(ValueError, match="spec_k"):
        ServingEngine(params, cfg, policy=policy, slots=2, max_len=16,
                      spec_k=-1)
    other = dataclasses.replace(cfg, vocab_size=32)
    with pytest.raises(ValueError, match="vocab"):
        ServingEngine(params, cfg, policy=policy, slots=2, max_len=16,
                      spec_k=2, draft_params=params, draft_cfg=other)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=16,
                        dtype=jnp.float32, spec_k=4)
    with pytest.raises(ValueError, match="spec_k"):
        eng.submit(PROMPT, max_new=9)       # 4+9+4 > 16: no verify headroom
    eng.submit(PROMPT, max_new=8)           # 4+8+4 == 16: fits


def test_spec_swa_within_window_works():
    """SWA arch with max_len <= window (no ring wrap) serves speculatively
    and stays parity-exact."""
    cfg, params, policy = _setup("dense")
    swa = dataclasses.replace(cfg, sliding_window=64)
    ref = _ref_tokens(params, swa, policy, max_new=5)
    eng = ServingEngine(params, swa, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, spec_k=3)
    eng.submit(PROMPT, max_new=5)
    done = eng.run_all()
    assert done[0].out == ref


def test_spec_kv8_parity():
    """Speculation composes with the int8 KV cache: both caches quantized,
    rollback rewinds the scale arrays too."""
    cfg, params, policy = _setup("dense")
    ref = _ref_tokens(params, cfg, policy, max_new=5, kv_bits=8)
    eng = ServingEngine(params, cfg, policy=policy, slots=2, max_len=32,
                        dtype=jnp.float32, kv_bits=8, spec_k=3)
    eng.submit(PROMPT, max_new=5)
    done = eng.run_all()
    assert done[0].out == ref
