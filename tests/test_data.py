"""Data substrate: determinism (the elastic-restart property) + task stats."""
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import ClassificationTask, digit_task, lm_batch


def test_lm_batch_deterministic():
    a = lm_batch(jnp.asarray(0), jnp.asarray(7), batch=4, seq=16, vocab=64)
    b = lm_batch(jnp.asarray(0), jnp.asarray(7), batch=4, seq=16, vocab=64)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = lm_batch(jnp.asarray(0), jnp.asarray(8), batch=4, seq=16, vocab=64)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_lm_batch_is_learnable_shifted_stream():
    d = lm_batch(jnp.asarray(0), jnp.asarray(0), batch=2, seq=32, vocab=64)
    # labels are the next-token stream of tokens
    np.testing.assert_array_equal(np.asarray(d["tokens"][:, 1:]),
                                  np.asarray(d["labels"][:, :-1]))


def test_classification_task_paper_stats():
    t = digit_task(n_train=500, n_test=200)
    x, y = t.train
    assert x.shape == (500, 784) and x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))
    # deterministic across constructions
    t2 = digit_task(n_train=500, n_test=200)
    np.testing.assert_array_equal(t.train[0], t2.train[0])


def test_task_difficulty_scales_with_noise():
    easy = ClassificationTask(128, 5, noise=0.1, n_train=300, n_test=300)
    hard = ClassificationTask(128, 5, noise=3.0, n_train=300, n_test=300)

    def np_err(t):
        x, y = t.test
        d = ((x[:, None, :] - t.prototypes[None]) ** 2).sum(-1)
        return (d.argmin(1) != y).mean()

    assert np_err(easy) < np_err(hard)
