"""Quickstart: the paper's technique in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import QuantSpec, fake_quant, pack_matrix, quantize
from repro.kernels.qmatmul.ops import qmatmul
from repro.kernels.qmatvec.ops import qmatvec

key = jax.random.PRNGKey(0)

# 1. a weight matrix, like one layer of the paper's 784-1022-1022-1022-10 net
w = jax.random.normal(key, (784, 1022)) * 0.1

# 2. optimal uniform 3-bit quantization (paper step 2): levels in {-3..3}
spec = QuantSpec(bits=3)
q, delta = quantize(w, spec)
print(f"delta={float(delta):.4f}  levels {int(q.min())}..{int(q.max())}")
print(f"quant MSE: {float(jnp.mean((w - q * delta) ** 2)):.2e}")

# 3. STE fake-quant view — what the retraining forward pass sees (step 3)
wq = fake_quant(w, spec)
print(f"fake-quant unique levels: {len(jnp.unique(wq))} (<= 7)")

# 4. pack into the on-chip container format: 10 weights per int32 word
words = pack_matrix(q, 3)
print(f"packed: {w.size * 4 / 2**20:.2f} MB fp32 -> {words.nbytes / 2**20:.3f} MB "
      f"({w.size * 4 / words.nbytes:.1f}x smaller, paper's BRAM image)")

# 5. compute through the Pallas kernels (interpret mode on CPU)
x = jax.random.normal(key, (100, 784))                  # paper's batch of 100
y_kernel = qmatmul(x, q, jnp.broadcast_to(delta, (1022,)))
y_packed = qmatvec(x, words, jnp.broadcast_to(delta, (1022,)), k=784)
y_ref = x @ (q * delta)
print(f"qmatmul  vs ref: {float(jnp.max(jnp.abs(y_kernel - y_ref))):.2e}")
print(f"qmatvec  vs ref: {float(jnp.max(jnp.abs(y_packed - y_ref))):.2e}")
