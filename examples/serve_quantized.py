"""Serve a quantized LM with batched requests through the continuous-batching
engine — the paper's deployed form (container-packed weights, on-chip
dequantization path). One jitted decode step advances EVERY active slot per
tick, so the 3-bit weight stream is amortized across the whole batch — the
paper's Fig. 4 throughput argument.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import jax

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import W3A8
from repro.models import get_model
from repro.serving.engine import ServingEngine, generate

cfg = reduced(get_config("qwen2-1.5b"), layers=4, d_model=128, vocab=512)
mod = get_model(cfg)
params = mod.init(jax.random.PRNGKey(0), cfg)

# deploy: quantize + pack (the paper's "download to the accelerator" step)
serve_params = quant_dense.export_container(params, W3A8)
packed_bytes = sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(serve_params))
float_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree_util.tree_leaves(params))
print(f"deployed weights: {float_bytes / 2**20:.1f} MB fp32 -> "
      f"{packed_bytes / 2**20:.2f} MB packed")

# batched generation
prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab_size)
out = generate(serve_params, prompts, cfg, policy=W3A8, max_new_tokens=16)
print("batch generate:", out.shape)

# continuous batching over a request stream: requests are admitted into slots
# of ONE shared cache via length-bucketed batched prefill (same-bucket
# requests share one jitted prefill call); tokens are drained in bulk, never
# synced per token
eng = ServingEngine(serve_params, cfg, policy=W3A8, slots=4, max_len=64)
for i in range(6):
    eng.submit(list(range(1, 4 + (i % 3) * 4)), max_new=8)   # mixed lengths
done = eng.run_all()
for r in done:
    print(f"req {r.uid}: {r.out}")
print(f"{sum(len(r.out) for r in done)} tokens in {eng.decode_calls} batched "
      f"decode ticks / {eng.prefill_calls} bucketed prefill calls "
      f"(continuous batching keeps slots full)")
