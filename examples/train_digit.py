"""End-to-end reproduction of the paper's digit experiment (§2.1):
RBM pretrain -> float train -> optimal 3-bit quantization -> STE retrain ->
packed deployment check.

    PYTHONPATH=src python examples/train_digit.py          # quick (~2 min)
    PYTHONPATH=src python examples/train_digit.py --full   # paper recipe
"""
import argparse
import json

from repro.paper.pipeline import PaperRunConfig, run_paper_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper's full recipe: 1022-wide, 50+100+100 epochs")
    ap.add_argument("--task", default="digit", choices=["digit", "phoneme"])
    args = ap.parse_args()

    if args.full:
        rc = PaperRunConfig(task=args.task)
    else:
        rc = PaperRunConfig(task=args.task, hidden=(256, 256, 256),
                            pretrain_epochs=8, float_epochs=15,
                            retrain_epochs=10)
    metrics = run_paper_experiment(rc, log=print)
    print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                      for k, v in metrics.items()}, indent=2))


if __name__ == "__main__":
    main()
