"""Train a ~100M-param decoder LM with the paper's W3A8 QAT for a few hundred
steps (deliverable b: end-to-end driver) — quantized training loss should
track the float baseline closely.

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.configs import TrainConfig, get_config
from repro.core.precision import FLOAT, W3A8
from repro.data.pipeline import HostLoader
from repro.data.synthetic import lm_batch
from repro.models import get_model
from repro.training.loop import Trainer, make_train_step


def make_100m_cfg():
    """qwen2-style ~100M: 12L x d768 x ff2048, vocab 8192 (tied)."""
    return dataclasses.replace(
        get_config("qwen2-1.5b"), name="qwen2-100m", num_layers=12,
        d_model=768, num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=8192, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--quant", default="w3a8", choices=["float", "w3a8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = make_100m_cfg()
    print(f"model: {cfg.param_count() / 1e6:.0f}M params")
    policy = W3A8 if args.quant == "w3a8" else FLOAT
    tcfg = TrainConfig(learning_rate=3e-4, total_steps=args.steps,
                       warmup_steps=20, optimizer="adamw", remat="layer")

    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    step_fn, init_state = make_train_step(cfg, tcfg, policy)
    step_fn = jax.jit(step_fn, donate_argnums=0)
    loader = HostLoader(lambda seed, s: lm_batch(
        jnp.asarray(seed), jnp.asarray(s), batch=args.batch, seq=args.seq,
        vocab=cfg.vocab_size))

    ck = ckpt_lib.Checkpointer(args.ckpt_dir, keep=2)
    trainer = Trainer(step_fn, init_state(params), checkpointer=ck,
                      ckpt_every=100, log_every=20)
    trainer.run(loader, args.steps,
                on_log=lambda r: print(
                    f"step {r['step']:4d} loss {r['loss']:.4f} "
                    f"acc {r['acc']:.3f} {r['dt'] * 1e3:.0f}ms"))
    print(f"straggler stats: {trainer.monitor.slow_steps}/"
          f"{trainer.monitor.total_steps} slow steps")


if __name__ == "__main__":
    main()
