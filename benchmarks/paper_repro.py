"""Paper-claims reproduction run (EXPERIMENTS.md §Repro):
full-size digit + phoneme nets through the paper's full recipe
(50 RBM epochs/layer + 100 float + 100 QAT epochs)."""
import json
import sys

from repro.paper.pipeline import PaperRunConfig, run_paper_experiment


def main(out_path="results/paper_repro.json", fast=False):
    results = {}
    for task in ("digit", "phoneme"):
        rc = PaperRunConfig(task=task) if not fast else PaperRunConfig(
            task=task, pretrain_epochs=3, float_epochs=3, retrain_epochs=2,
            hidden=(128, 128))
        results[task] = run_paper_experiment(rc, log=print)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=2)
    print(json.dumps(results, indent=2))


if __name__ == "__main__":
    main(*sys.argv[1:])
