"""Paper §4 throughput analogue.

Measured: µs/image of the digit net on this CPU (float vs fake-quant vs
packed-kernel path) at the paper's batch 100. Derived: TPU v5e roofline
images/s for the W3-on-chip deployment (the paper's FPGA hit 70k img/s,
Titan Black GPU 250k img/s — Table in §4).
"""
from __future__ import annotations

import time

import jax

from repro.core.precision import FLOAT, W3A8
from repro.models import dnn

BATCH = 100                      # paper batch
NET = (784, (1022, 1022, 1022), 10)
N_MACS = 784 * 1022 + 1022 * 1022 * 2 + 1022 * 10   # per image
V5E_FLOPS = 197e12
V5E_HBM = 819e9


def _time(fn, *args, reps=20):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run():
    key = jax.random.PRNGKey(0)
    params = dnn.init(key, NET[0], NET[1], NET[2])
    x = jax.random.uniform(key, (BATCH, NET[0]))

    f_float = jax.jit(lambda p, x: dnn.forward(p, x, policy=FLOAT))
    f_w3 = jax.jit(lambda p, x: dnn.forward(p, x, policy=W3A8))
    t_float = _time(f_float, params, x)
    t_w3 = _time(f_w3, params, x)

    rows = [
        ("digit.cpu.float", t_float / BATCH * 1e6,
         f"imgs_per_s={BATCH / t_float:.0f}"),
        ("digit.cpu.w3a8_fakequant", t_w3 / BATCH * 1e6,
         f"imgs_per_s={BATCH / t_w3:.0f}"),
    ]

    # derived v5e roofline: per image 2*N_MACS flops; weights on-chip (VMEM
    # resident, 1.2MB packed) => no HBM weight traffic, compute-bound
    flops_img = 2 * N_MACS
    imgs_compute = V5E_FLOPS / flops_img
    # weights-from-HBM comparison (if NOT on-chip): 3.04M weights x 4B
    imgs_hbm_fp32 = V5E_HBM / (3.04e6 * 4)
    imgs_hbm_w3 = V5E_HBM / (3.04e6 * 0.4)
    rows += [
        ("digit.v5e.onchip_roofline", 1e6 / imgs_compute,
         f"imgs_per_s={imgs_compute:.2e};paper_fpga=7.0e4;paper_gpu=2.5e5"),
        ("digit.v5e.hbm_fp32_roofline", 1e6 / imgs_hbm_fp32,
         f"imgs_per_s={imgs_hbm_fp32:.2e}"),
        ("digit.v5e.hbm_w3_roofline", 1e6 / imgs_hbm_w3,
         f"imgs_per_s={imgs_hbm_w3:.2e}"),
    ]
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
