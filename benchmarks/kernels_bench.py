"""Kernel micro-bench: packed-weight paths vs float matmul on this CPU
(numbers are CPU-relative; the TPU story is the roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.packing import pack_matrix
from repro.kernels.qmatmul.ref import qmatmul_ref
from repro.kernels.qmatvec.ref import qmatvec_ref


def _time(fn, *args, reps=10):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    key = jax.random.PRNGKey(0)
    m, k, n = 100, 1022, 1022
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(key, (k, n))
    q = jax.random.randint(key, (k, n), -3, 4, jnp.int8)
    wp = pack_matrix(q, 3)
    d = jnp.ones((n,)) * 0.1

    f_float = jax.jit(lambda x, w: x @ w)
    f_q = jax.jit(lambda x, q, d: qmatmul_ref(x, q, d))
    f_qp = jax.jit(lambda x, wp, d: qmatvec_ref(x, wp, d, k))
    return [
        ("kernel.cpu.matmul_f32", _time(f_float, x, w), f"shape={m}x{k}x{n}"),
        ("kernel.cpu.qmatmul_ref", _time(f_q, x, q, d), "int8 levels + delta"),
        ("kernel.cpu.qmatvec_ref", _time(f_qp, x, wp, d),
         "3.2-bit containers unpacked in-graph"),
    ]


def main():
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
