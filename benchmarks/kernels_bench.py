"""Kernel micro-bench + interpret-mode regression gate for the serve-path
matmuls AND the fused decode-attention kernel.

Two matmul shape cases mirror the LM serve path exactly:

  decode    (B=slots, K) x (K, N)            — one engine tick
  prefill   (slots*bucket_len, K) x (K, N)   — one bucketed admission

and three implementations per case:

  matmul_f32      float weights (the GPU-like baseline)
  dequant.q/qp    the fused serve fallback (quant_dense.serve_apply,
                  mode='dequant'): levels matmul'd in the activation dtype,
                  delta applied to the output — what 'auto' runs off-TPU
  kernel.q/qp     the Pallas qmatmul (levels) / qmatvec (containers) kernels
                  in interpret mode — numerics-exact stand-in for the TPU
                  path; timed only with --smoke-size shapes (interpret is an
                  emulator, its timings are not meaningful)

The attention cases mirror the three attention serving paths:

  decode        one engine tick — B=slots rows at mixed valid lengths
                against a (B, S, KV, D) cache; the fused
                ``kernels.attn_decode`` kernel (interpret mode) is
                parity-checked against BOTH its pure-jnp oracle
                (``attn_decode/ref.py``) and the production einsum path
                (``models.attention.decode_attention``).
  prefill       one bucketed admission — T x T prompt self-attention at
                T in {128, 512, 2048} (smoke: 24) with mixed per-row
                prompt lengths; the blocked online-softmax
                ``kernels.attn_prefill`` kernel is parity-checked against
                its einsum oracle (``attn_prefill/ref.py``) for bf16-class
                AND int8 KV, and each row's derived field quantifies the
                fp32 score-tensor bytes the einsum materializes in HBM vs
                the one VMEM tile the kernel holds.
  verify        one speculative tick — T = spec_k+1 in {3, 5} query rows
                against the live cache at mixed per-row frontiers; same
                kernel (T-row specialization), parity-checked against the
                oracle and the production guarded einsum
                (``models.attention.verify_attention``).

Every kernel case is PARITY-CHECKED; any mismatch exits nonzero, which is
the CI kernel-regression gate (`--smoke`). Each kernel case also carries a
``vmem_KB`` field — the static per-pallas_call on-chip working-set
estimate from ``repro.analysis`` (the same estimator the contract
linter's VMEM-budget pass gates on). Results are written to a JSON
artifact (default ``BENCH_kernels.json``) and archived next to
BENCH_serving.json.

    PYTHONPATH=src python benchmarks/kernels_bench.py           # timings
    PYTHONPATH=src python benchmarks/kernels_bench.py --smoke   # CI gate
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant_dense
from repro.core.packing import pack_matrix
from repro.core.precision import W3A8

# serve-path shapes: slots=8 decode tick, 8 slots x 16-token bucket prefill
FULL_CASES = [("decode", 8, 1024, 1024), ("prefill", 8 * 16, 1024, 1024)]
SMOKE_CASES = [("decode", 8, 96, 128), ("prefill", 8 * 16, 96, 128)]

# attn_decode shapes: (B=slots, S cache, H heads, KV heads, D head_dim)
ATTN_FULL = (8, 512, 8, 2, 64)
ATTN_SMOKE = (8, 96, 8, 2, 16)

# attn_prefill shapes: (B, T) bucketed-admission self-attention (S = T) and
# (B, T, S) speculative verify (T = spec_k+1 rows against the live cache);
# heads (H, KV, D) shared
PREFILL_FULL = [(4, 128), (4, 512), (1, 2048)]
PREFILL_SMOKE = [(2, 24)]
VERIFY_FULL = [(8, 3, 512), (8, 5, 512)]
VERIFY_SMOKE = [(4, 3, 48)]
PF_HEADS_FULL = (8, 2, 64)
PF_HEADS_SMOKE = (4, 2, 16)


def _vmem_kb(fn, *args):
    """Static on-chip working-set estimate for every pallas_call in the
    traced graph (repro.analysis: double-buffered block tiles + scratch,
    read off the BlockSpecs/grid — nothing is executed). Returns the
    LARGEST single kernel's estimate in KiB: kernels run one at a time,
    so the max is what must fit VMEM."""
    from repro.analysis.jaxpr_utils import find_pallas_eqns
    from repro.analysis.vmem import pallas_vmem_estimate

    jx = jax.make_jaxpr(lambda *a: fn(*a))(*args)
    ests = [pallas_vmem_estimate(e)["vmem_bytes"]
            for e in find_pallas_eqns(jx)]
    return max(ests, default=0) / 2 ** 10


def _time(fn, *args, reps=10):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def _leaves(key, k, n):
    kx, kw = jax.random.split(key)
    w = jax.random.normal(kw, (k, n))
    q = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
    d = jnp.abs(jax.random.normal(kx, (n,))) * 0.1 + 0.01
    b = jax.random.normal(kx, (n,)) * 0.1
    qp = pack_matrix(q, 3)
    delta = d.reshape(1, n)
    return {
        "w": w,
        "q": {"q": q, "delta": delta, "b": b},
        "qp": {"qp": qp, "delta": delta, "b": b},
    }


def _parity(case, form, leaf, x, out):
    """Kernel output vs the dequantized effective_weight oracle."""
    w = quant_dense.effective_weight(leaf, W3A8, "hidden", k=x.shape[-1])
    ref = x @ w.astype(x.dtype) + leaf["b"]
    err = float(jnp.max(jnp.abs(out - ref)))
    ok = bool(np.allclose(np.asarray(out), np.asarray(ref),
                          rtol=1e-4, atol=1e-4))
    return {"case": f"{case}.{form}", "max_abs_err": err, "ok": ok}


def attn_cases(smoke: bool = False):
    """Fused decode-attention parity: kernel vs ref.py vs decode_attention,
    bf16-class (f32 on CPU) and int8 cache, mixed per-row valid lengths."""
    from repro.kernels.attn_decode.ops import attn_decode
    from repro.kernels.attn_decode.ref import attn_decode_ref
    from repro.models.attention import decode_attention
    from repro.models.transformer import _quantize_kv

    b, s, h, kv, d = ATTN_SMOKE if smoke else ATTN_FULL
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, s, kv, d))
    vc = jax.random.normal(ks[2], (b, s, kv, d))
    lens = (jnp.arange(b) * (s // b) % s + 1).astype(jnp.int32)  # mixed rows
    kq, ksc = _quantize_kv(kc)
    vq, vsc = _quantize_kv(vc)

    rows, parity = [], []
    reps = 3 if smoke else 10
    shape = f"shape={b}x{s}x{h}x{kv}x{d}"
    for name, args in (("bf16", (q, kc, vc, lens, None, None)),
                       ("int8", (q, kq, vq, lens, ksc, vsc))):
        f_kn = jax.jit(lambda *a: attn_decode(*a, interpret=True))
        out = f_kn(*args)
        ref = attn_decode_ref(*args)
        ein = decode_attention(*args, mode="ref")
        vkb = _vmem_kb(f_kn, *args)
        for oracle, o in (("ref", ref), ("einsum", ein)):
            err = float(jnp.max(jnp.abs(out - o)))
            ok = bool(np.allclose(np.asarray(out), np.asarray(o),
                                  rtol=1e-4, atol=1e-4))
            parity.append({"case": f"attn_decode.{name}.vs_{oracle}",
                           "max_abs_err": err, "ok": ok,
                           "vmem_kb": round(vkb, 1)})
        f_ref = jax.jit(lambda *a: decode_attention(*a, mode="ref"))
        rows.append((f"kernel.cpu.attn_decode.{name}.einsum",
                     _time(f_ref, *args, reps=reps), shape))
        if smoke:
            rows.append((f"kernel.cpu.attn_decode.{name}.kernel.interpret",
                         _time(f_kn, *args, reps=reps),
                         f"{shape};vmem_KB={vkb:.1f}"))
    return rows, parity


def attn_prefill_cases(smoke: bool = False):
    """Blocked prefill/verify attention: kernel (interpret) vs its einsum
    oracle (attn_prefill/ref.py), bf16-class and int8 KV, mixed per-row
    lengths/frontiers; derived fields quantify the fp32 score bytes the
    einsum puts in HBM vs the single VMEM tile the kernel holds."""
    from repro.kernels.attn_prefill.ops import attn_prefill
    from repro.kernels.attn_prefill.ref import attn_prefill_ref
    from repro.models.attention import verify_attention
    from repro.models.transformer import _quantize_kv

    h, kv, d = PF_HEADS_SMOKE if smoke else PF_HEADS_FULL
    g = h // kv
    reps = 3 if smoke else 10
    rows, parity = [], []

    def oracle(q, k, v, hi, ks_=None, vs_=None):
        b, t = q.shape[:2]
        qg = (q * (d ** -0.5)).reshape(b, t, kv, g, d)
        lo = jnp.zeros((b, t), jnp.int32)
        return attn_prefill_ref(qg, k, v, lo, hi, ks_,
                                vs_).reshape(q.shape)

    def one(tag, q, k, v, hi, ks_=None, vs_=None):
        """Parity-check one case; returns (kernel_fn, shape+derived str)."""
        b, t = q.shape[:2]
        s = k.shape[1]
        f_kn = jax.jit(lambda *a: attn_prefill(
            a[0], a[1], a[2], a[3], k_scale=a[4] if len(a) > 4 else None,
            v_scale=a[5] if len(a) > 5 else None, interpret=True))
        args = (q, k, v, hi) + (() if ks_ is None else (ks_, vs_))
        out = f_kn(*args)
        ref = oracle(q, k, v, hi, ks_, vs_)
        err = float(jnp.max(jnp.abs(out - ref)))
        ok = bool(np.allclose(np.asarray(out), np.asarray(ref),
                              rtol=1e-4, atol=1e-4))
        vkb = _vmem_kb(f_kn, *args)
        parity.append({"case": tag, "max_abs_err": err, "ok": ok,
                       "vmem_kb": round(vkb, 1)})
        ein_mb = b * kv * g * t * s * 4 / 2 ** 20     # (B,KV,G,T,S) fp32
        tile_kb = min(128, t) * g * min(128, s) * 4 / 2 ** 10
        shape = (f"shape={b}x{t}x{s}x{h}x{kv}x{d};"
                 f"score_einsum_MB={ein_mb:.2f};score_tile_KB={tile_kb:.1f};"
                 f"vmem_KB={vkb:.1f}")
        return f_kn, args, shape

    # bucketed admission: T x T self-attention, mixed per-row prompt lengths
    for b, t in (PREFILL_SMOKE if smoke else PREFILL_FULL):
        ks3 = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks3[0], (b, t, h, d))
        kc = jax.random.normal(ks3[1], (b, t, kv, d))
        vc = jax.random.normal(ks3[2], (b, t, kv, d))
        lens = jnp.maximum((jnp.arange(b) + 1) * t // b, 1).astype(jnp.int32)
        pos = jnp.arange(t, dtype=jnp.int32)
        hi = jnp.minimum(pos[None, :] + 1, lens[:, None])
        kq, ksc = _quantize_kv(kc)
        vq, vsc = _quantize_kv(vc)
        for name, args in (("bf16", (q, kc, vc, hi)),
                           ("int8", (q, kq, vq, hi, ksc, vsc))):
            f_kn, full_args, shape = one(f"attn_prefill.T{t}.{name}", *args)
            f_ein = jax.jit(lambda *a: oracle(*a))
            rows.append((f"kernel.cpu.attn_prefill.T{t}.{name}.einsum",
                         _time(f_ein, *args, reps=reps), shape))
            rows.append((f"kernel.cpu.attn_prefill.T{t}.{name}"
                         f".kernel.interpret",
                         _time(f_kn, *full_args, reps=reps), shape))

    # speculative verify: T = spec_k+1 rows against the live cache
    for b, t, s in (VERIFY_SMOKE if smoke else VERIFY_FULL):
        ks3 = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(ks3[0], (b, t, h, d))
        kc = jax.random.normal(ks3[1], (b, s, kv, d))
        vc = jax.random.normal(ks3[2], (b, s, kv, d))
        pos0 = ((jnp.arange(b) * (s // b)) % (s - t)).astype(jnp.int32)
        valid = pos0[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :] + 1
        kq, ksc = _quantize_kv(kc)
        vq, vsc = _quantize_kv(vc)
        for name, args in (("bf16", (q, kc, vc, valid)),
                           ("int8", (q, kq, vq, valid, ksc, vsc))):
            f_kn, full_args, shape = one(f"attn_verify.T{t}.{name}", *args)
            out = f_kn(*full_args)
            # also gate against the PRODUCTION guarded-einsum verify path
            scales = args[4:] if len(args) > 4 else (None, None)
            ein = verify_attention(args[0], args[1], args[2], args[3],
                                   *scales, mode="ref")
            err = float(jnp.max(jnp.abs(out - ein)))
            ok = bool(np.allclose(np.asarray(out), np.asarray(ein),
                                  rtol=1e-4, atol=1e-4))
            parity.append({"case": f"attn_verify.T{t}.{name}.vs_production",
                           "max_abs_err": err, "ok": ok})
            f_ein = jax.jit(lambda a0, a1, a2, a3, *sc: verify_attention(
                a0, a1, a2, a3, *sc, mode="ref"))
            rows.append((f"kernel.cpu.attn_verify.T{t}.{name}.einsum",
                         _time(f_ein, *args, reps=reps), shape))
            if smoke:
                rows.append((f"kernel.cpu.attn_verify.T{t}.{name}"
                             f".kernel.interpret",
                             _time(f_kn, *full_args, reps=reps), shape))
    return rows, parity


def run_cases(smoke: bool = False):
    rows, parity = [], []
    reps = 3 if smoke else 10
    for case, m, k, n in (SMOKE_CASES if smoke else FULL_CASES):
        leaves = _leaves(jax.random.PRNGKey(0), k, n)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
        shape = f"shape={m}x{k}x{n}"

        f_float = jax.jit(lambda x, w: x @ w)
        rows.append((f"kernel.cpu.{case}.matmul_f32",
                     _time(f_float, x, leaves["w"], reps=reps), shape))
        for form in ("q", "qp"):
            leaf = leaves[form]
            f_dq = jax.jit(lambda x, lf=leaf: quant_dense.serve_apply(
                lf, x, mode="dequant"))
            rows.append((f"kernel.cpu.{case}.dequant.{form}",
                         _time(f_dq, x, reps=reps), shape))
            # interpret-mode Pallas path: parity-checked always, timed only
            # at smoke sizes (the interpret emulator's speed is meaningless)
            f_kn = jax.jit(lambda x, lf=leaf: quant_dense.serve_apply(
                lf, x, mode="kernel", interpret=True))
            out = f_kn(x)
            p = _parity(case, form, leaf, x, out)
            p["vmem_kb"] = round(_vmem_kb(f_kn, x), 1)
            parity.append(p)
            if smoke:
                rows.append((f"kernel.cpu.{case}.kernel.{form}.interpret",
                             _time(f_kn, x, reps=reps),
                             f"{shape};vmem_KB={p['vmem_kb']}"))
    arows, aparity = attn_cases(smoke=smoke)
    prows, pparity = attn_prefill_cases(smoke=smoke)
    return rows + arows + prows, parity + aparity + pparity


def run(smoke: bool = True):
    """Harness entry (benchmarks/run.py): flat name,us,derived rows."""
    rows, parity = run_cases(smoke=smoke)
    return rows + [(f"kernel.parity.{p['case']}", 0.0,
                    f"max_abs_err={p['max_abs_err']:.2e};"
                    f"{'ok' if p['ok'] else 'FAIL'}") for p in parity]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + exit nonzero on any kernel-vs-"
                         "oracle parity failure (the CI gate)")
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args()

    rows, parity = run_cases(smoke=args.smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    bad = [p for p in parity if not p["ok"]]
    for p in parity:
        print(f"parity.{p['case']},{p['max_abs_err']:.2e},"
              f"{'ok' if p['ok'] else 'FAIL'}")

    if args.out:
        artifact = {"bench": "kernels", "smoke": args.smoke,
                    "rows": [{"name": n, "us": us, "derived": d}
                             for n, us, d in rows],
                    "parity": parity}
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}")

    if bad:
        raise SystemExit(f"kernel parity FAILED: {[p['case'] for p in bad]}")


if __name__ == "__main__":
    main()
