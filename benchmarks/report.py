"""Regenerate EXPERIMENTS.md tables from results/ (idempotent).

Fills the <!-- REPRO_TABLE -->, <!-- ROOFLINE_TABLE -->,
<!-- ROOFLINE_SUMMARY --> and <!-- PERF_LOG --> markers.
"""
from __future__ import annotations

import json
import os
import re

from benchmarks import roofline as rl

EXP = "EXPERIMENTS.md"


def repro_table() -> str:
    path = "results/paper_repro.json"
    if not os.path.exists(path):
        return "_paper repro run pending_"
    d = json.load(open(path))
    out = ("| task | float MCR % | direct-quant % | W3A8 retrained % | gap pp "
           "| paper gap pp | compression |\n|---|---|---|---|---|---|---|\n")
    paper_gap = {"digit": 0.02, "phoneme": 0.58}
    for task, m in d.items():
        out += (f"| {task} | {m['float_mcr']:.2f} | {m['direct_quant_mcr']:.2f} "
                f"| {m['w3a8_mcr']:.2f} | {m['gap_pp']:+.2f} | "
                f"+{paper_gap[task]:.2f} | "
                f"{m['weight_bytes_float'] / m['weight_bytes_packed']:.1f}x |\n")
    return out


_SENTENCES = {
    ("decode", "memory"): ("W3 containers already cut weight traffic 5x vs bf16; "
                           "next lever: fuse dequant into the matvec (Pallas qmatvec on "
                           "real TPU) and shard the KV cache over every free mesh axis."),
    ("decode", "collective"): ("replicate small kv projections to kill the score "
                               "all-reduce; keep logits vocab-sharded."),
    ("prefill", "memory"): ("larger attention chunks cut online-softmax "
                            "rescale traffic; int8 activations halve stream bytes."),
    ("prefill", "compute"): ("causal-chunk skipping halves masked-out QK^T work; "
                             "MXU-aligned chunk sizes keep the matmuls dense."),
    ("prefill", "collective"): ("all-gather of level weights amortizes over the whole "
                                "32k sequence — move TP all-reduce to reduce-scatter+"
                                "all-gather overlap."),
    ("train", "memory"): ("remat policy recomputes the whole layer; switching to "
                          "dots-saveable or larger microbatches cuts recompute bytes."),
    ("train", "collective"): ("FSDP all-gathers dominate: bigger microbatches amortize "
                              "them; int8 gradient compression shrinks cross-pod "
                              "all-reduce 4x (distributed.compression)."),
    ("train", "compute"): ("close to the flop roof: fold fake-quant into the matmul "
                           "epilogue and drop fp32 upcasts in softmax/norms."),
}


def roofline_summary(rows) -> str:
    out = ("| arch | shape | dominant | next lever (one sentence) |\n"
           "|---|---|---|---|\n")
    for r in rows:
        if r["mesh"] != "single":
            continue
        kind = ("train" if "train" in r["shape"] else
                "prefill" if "prefill" in r["shape"] else "decode")
        s = _SENTENCES.get((kind, r["dominant"]), "")
        out += f"| {r['arch']} | {r['shape']} | {r['dominant']} | {s} |\n"
    return out


def dryrun_table() -> str:
    """Per-cell dry-run record: per-device memory, flops, collective mix."""
    import glob

    rows = []
    for f in sorted(glob.glob("results/dryrun/*.json")):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        full = r["full"]
        mem = full["memory"]
        coll = full["collectives"]
        kinds = "+".join(
            f"{k.split('-')[0]}{int(coll[k] / 2**20)}M" for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute") if coll.get(k, 0) > 0) or "none"
        rows.append((r["arch"], r["shape"], r["mesh"],
                     mem.get("peak_bytes_est", 0) / 2**30,
                     full["cost"]["flops"] / 1e12,
                     coll.get("count", 0), kinds, full["compile_s"]))
    rows.sort()
    out = ("| arch | shape | mesh | peak GB/dev | HLO TFLOP (body-once) | "
           "#coll | collective mix (MB, body-once) | compile s |\n"
           "|---|---|---|---|---|---|---|---|\n")
    for a, s, m, gb, tf, nc, kinds, cs in rows:
        out += (f"| {a} | {s} | {m} | {gb:.1f} | {tf:.2f} | {nc} | {kinds} "
                f"| {cs} |\n")
    return out


def perf_log() -> str:
    path = "results/perf_log.json"
    if not os.path.exists(path):
        return "_hillclimb pending_"
    log = json.load(open(path))
    out = ""
    for cell, entries in log.items():
        out += f"\n### {cell}\n\n"
        out += ("| iter | change | hypothesis | dominant before (s) | after (s) "
                "| Δ | verdict |\n|---|---|---|---|---|---|---|\n")
        for i, e in enumerate(entries):
            out += (f"| {i} | {e['change']} | {e['hypothesis']} | "
                    f"{e['before']:.3e} | {e['after']:.3e} | "
                    f"{(e['after'] - e['before']) / max(e['before'], 1e-12) * 100:+.1f}% "
                    f"| {e['verdict']} |\n")
        if entries and "summary" in entries[-1]:
            out += f"\n{entries[-1]['summary']}\n"
    return out


def fill(marker: str, content: str, text: str) -> str:
    pat = re.compile(rf"<!-- {marker} -->.*?(?=\n## |\n<!-- |\Z)", re.S)
    repl = f"<!-- {marker} -->\n\n{content}\n"
    if pat.search(text):
        return pat.sub(repl.replace("\\", "\\\\"), text, count=1)
    return text


def main():
    rows = rl.load_all()
    json.dump(rows, open("results/roofline.json", "w"), indent=2)
    text = open(EXP).read()
    text = fill("REPRO_TABLE", repro_table(), text)
    text = fill("DRYRUN_TABLE", dryrun_table(), text)
    text = fill("ROOFLINE_TABLE", rl.markdown_table(rows), text)
    text = fill("ROOFLINE_SUMMARY", roofline_summary(rows), text)
    text = fill("PERF_LOG", perf_log(), text)
    open(EXP, "w").write(text)
    print("EXPERIMENTS.md regenerated")


if __name__ == "__main__":
    main()
