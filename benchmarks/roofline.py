"""Roofline assembly (deliverable g): three terms per (arch x shape) cell from
the dry-run JSONs in results/dryrun/.

Methodology notes (see EXPERIMENTS.md §Roofline):
  * compiled.cost_analysis() on the partitioned module returns PER-DEVICE
    flops/bytes (shapes in the SPMD program are per-partition), so terms are
    per-chip directly — equivalent to HLO_total/(chips x peak) under load
    balance.
  * XLA counts while-loop bodies ONCE. Totals are reconstructed from the
    L0/L1 (hybrid: L0/G1/A1) reduced-depth lowerings:
        per_layer = C(L1) - C(L0);   total = C(L0) + L * per_layer
    hybrid:  per_g(A) = C(G1)-C(L0); per_g(1) = C(A1)-C(L0)
             m = (per_g(A)-per_g(1))/(A-1); a = per_g(1)-m
             total = C(L0) + G*(A*m + a) + tail*m
  * collective term assumes one ICI link per op (conservative serial model).

Hardware constants (TPU v5e class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = {"single": 256, "multi": 512}

RESULTS_DIR = "results/dryrun"


def _costs(rec: Dict) -> Dict[str, float]:
    c = rec["cost"]
    return {"flops": c["flops"], "bytes": c["bytes"],
            "coll": rec["collectives"].get("total", 0.0)}


def _depth_combine(rec: Dict, suffix: str = "") -> Dict[str, float]:
    """Undo body-once loop counting via the L0/L1 (hybrid L0/G1/A1) system."""
    l = rec["num_layers"]
    if rec.get("attn_every"):                          # hybrid decomposition
        a = rec["attn_every"]
        g, tail = l // a, l % a
        l0 = _costs(rec["L0" + suffix])
        pg_a = {k: _costs(rec["G1" + suffix])[k] - l0[k] for k in l0}
        pg_1 = {k: _costs(rec["A1" + suffix])[k] - l0[k] for k in l0}
        out = {}
        for k in l0:
            m = (pg_a[k] - pg_1[k]) / max(a - 1, 1)
            att = pg_1[k] - m
            out[k] = l0[k] + g * (a * m + att) + tail * m
        return out
    l0 = _costs(rec["L0" + suffix])
    l1 = _costs(rec["L1" + suffix])
    return {k: l0[k] + l * (l1[k] - l0[k]) for k in l0}


def _quad_extrapolate(xs, ys, x: float) -> float:
    """Exact Lagrange quadratic through 3 samples, evaluated at x."""
    (x0, x1, x2), (y0, y1, y2) = xs, ys
    return (y0 * (x - x1) * (x - x2) / ((x0 - x1) * (x0 - x2)) +
            y1 * (x - x0) * (x - x2) / ((x1 - x0) * (x1 - x2)) +
            y2 * (x - x0) * (x - x1) / ((x2 - x0) * (x2 - x1)))


def _combine(rec: Dict) -> Optional[Dict[str, float]]:
    """Reconstruct whole-model per-device costs from the aux lowerings."""
    if "full" not in rec or rec.get("status") != "ok":
        return None
    try:
        if rec.get("aux_scheme") == "seqfit":
            # per-sample depth combine, then exact quadratic-in-S fit
            # (every cost term is polynomial <=2 in sequence length)
            xs = rec["seq_samples"]
            totals = [_depth_combine(rec, f"@{s}") for s in xs]
            return {k: max(_quad_extrapolate(xs, [t[k] for t in totals],
                                             rec["seq_len"]), 0.0)
                    for k in totals[0]}
        return _depth_combine(rec)
    except KeyError:
        # aux lowering missing (multi-pod cells) — body-once numbers only
        return None


def _cfg_of(rec):
    from repro.configs import get_config
    return get_config(rec["arch"])


def n_matmul_params(rec: Dict) -> float:
    """Active params participating in matmuls: embedding-table gathers do no
    flops, so subtract one vocab x d (the head matmul stays — tied or not)."""
    cfg = _cfg_of(rec)
    n = rec["active_params"]
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model
    return float(n)


def model_flops_per_step(rec: Dict) -> float:
    """Analytic MODEL_FLOPS (global): 6*N*D train / 2*N*D inference, N =
    matmul-active params (embed gather excluded)."""
    n = n_matmul_params(rec)
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    tokens = rec["global_batch"]          # decode: one token per sequence
    return 2.0 * n * tokens


def useful_bytes_per_chip(rec: Dict) -> float:
    """Minimal per-chip HBM traffic for one step (the memory roofline's
    denominator): weights read once (packed widths for w3) + decode KV/state
    traffic. Activations/grads excluded (lower bound)."""
    cfg = _cfg_of(rec)
    chips = CHIPS[rec["mesh"]]
    n_active = rec["active_params"]
    embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    hidden = max(n_active - embed, 0)
    if rec["kind"] == "train":
        # fp32 master read + grad write + 2 Adam moments read/write ~ 16B/param
        wbytes = rec["params"] * 16.0
    elif rec["quant"] in ("w3", "w3levels"):
        wbytes = hidden * 0.4 + embed * 1.0          # containers + int8
    else:
        wbytes = n_active * 2.0                      # bf16
    cache = 0.0
    if rec["kind"] == "decode":
        s = min(rec["seq_len"], cfg.sliding_window or rec["seq_len"])
        kv_bytes = 1 if rec.get("knobs", {}).get("kv8") else 2
        if cfg.num_heads and cfg.family != "hybrid":
            cache = (cfg.num_layers * rec["global_batch"] * s *
                     cfg.num_kv_heads * cfg.head_dim * 2 * kv_bytes)
        if cfg.family in ("ssm", "hybrid"):
            cache += (cfg.num_layers * rec["global_batch"] * cfg.ssm_heads *
                      cfg.ssm_headdim * cfg.ssm_state * 4 * 2)
        if cfg.family == "hybrid":
            napps = cfg.num_layers // cfg.attn_every
            cache += (napps * rec["global_batch"] * rec["seq_len"] *
                      cfg.num_kv_heads * cfg.head_dim * 2 * 2)
    return wbytes / chips + cache / chips


def analyze_cell(rec: Dict) -> Optional[Dict]:
    comb = _combine(rec)
    chips = CHIPS[rec["mesh"]]
    mf = model_flops_per_step(rec) / chips      # per-chip useful flops
    if comb is None:
        comb = _costs(rec["full"]) if rec.get("status") == "ok" else None
        exact = False
        if comb is None:
            return None
    else:
        exact = True
    t_compute = comb["flops"] / PEAK_FLOPS
    t_memory = comb["bytes"] / HBM_BW
    t_coll = comb["coll"] / LINK_BW
    bound = max(t_compute, t_memory, t_coll)
    dominant = ("compute" if bound == t_compute else
                "memory" if bound == t_memory else "collective")
    ub = useful_bytes_per_chip(rec)
    # roofline fraction: time the IDEAL machine needs (max of useful-flop and
    # useful-byte roofs) over the achieved HLO-derived bound. MFU-style for
    # compute-bound cells, BW-utilization-style for decode.
    ideal = max(mf / PEAK_FLOPS, ub / HBM_BW)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "quant": rec.get("quant", "w3"), "exact_loops": exact,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": comb["flops"],
        "useful_ratio": mf / comb["flops"] if comb["flops"] else 0.0,
        "mfu_at_bound": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "bwu_at_bound": (ub / HBM_BW) / bound if bound else 0.0,
        "roofline_fraction": ideal / bound if bound else 0.0,
        "step_bound_s": bound,
        "memory_per_dev_gb": rec["full"]["memory"].get("peak_bytes_est", 0) / 2**30,
    }


def load_all(results_dir: str = RESULTS_DIR):
    out = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        # params in the JSON may predate config fixes — recompute analytically
        cfg = _cfg_of(rec)
        rec["params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
        a = analyze_cell(rec)
        if a:
            out.append(a)
    return out


def markdown_table(rows, mesh="single", quant="w3") -> str:
    rows = [r for r in rows if r["mesh"] == mesh and r["quant"] == quant]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful(MODEL/HLO) | roofline frac | mem/dev GB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
                 f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
                 f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
                 f"{r['roofline_fraction']:.3f} | "
                 f"{r['memory_per_dev_gb']:.1f} |\n")
    return hdr + body


def main():
    rows = load_all()
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=2)
    print(markdown_table(rows))
    # summary for benchmark CSV contract (single-pod = exact loop accounting;
    # multi-pod rows are compile/memory proof only, not roofline terms)
    for r in rows:
        if not r["exact_loops"]:
            continue
        print(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']},"
              f"{r['step_bound_s'] * 1e6:.1f},"
              f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
