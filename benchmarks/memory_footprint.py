"""Paper Table 1/2 analogue: weight storage by precision + on-chip verdicts.

The paper's question — "do the weights fit in on-chip memory?" — answered for
(a) its own two nets vs the XC7Z045's 2.18MB BRAM, and (b) every assigned LM
arch vs a v5e pod's aggregate VMEM/HBM per device on the 16x16 mesh.

LM rows also report the serving-side analogue: decode HBM traffic is
weights PLUS the KV cache, so each arch gets KV-cache bytes per token for
the bf16 cache vs the engine's ``kv_bits=8`` form (int8 entries + one fp32
k/v scale per layer-token) — the number that decides how many decode slots
a fixed cache budget holds.

Each attention arch also gets the prefill score-tensor comparison: admitting
a 2048-token prompt through the einsum path materializes a per-layer
(KV, G, T, S) fp32 score tensor per sequence, while the blocked Pallas
prefill kernel holds one (bt, G, bs) fp32 tile in VMEM — the HBM round-trip
the kernel eliminates (``pf32MB`` vs ``tileKB`` columns).
"""
from __future__ import annotations

from repro.configs import ARCH_IDS, get_config


def kv_bytes_per_token(cfg, kv_bits: int = 16) -> int:
    """KV-cache bytes appended per generated token.

    Transformer-family archs write K+V per layer; hybrid writes one KV pair
    per shared-attention application (num_layers // attn_every); ssm has no
    KV cache. ``kv_bits=8`` is int8 entries + two fp32 per-token scales per
    cache layer (k_scale, v_scale).
    """
    if cfg.family == "ssm":
        return 0
    layers = (cfg.num_layers // cfg.attn_every if cfg.family == "hybrid"
              else cfg.num_layers)
    hd = cfg.head_dim or cfg.d_model // cfg.num_heads
    per_layer = 2 * cfg.num_kv_heads * hd                  # K + V entries
    if kv_bits == 8:
        return layers * (per_layer + 2 * 4)                # int8 + 2 scales
    return layers * per_layer * kv_bits // 8

def prefill_score_bytes(cfg, t: int = 2048, bt: int = 128,
                        bs: int = 128) -> tuple[int, int]:
    """fp32 attention-score bytes live while admitting a ``t``-token prompt
    (per layer, per sequence): einsum path vs the blocked prefill kernel.

    The einsum reference builds the full (KV, G, T, S) score tensor with
    S = T; the kernel's online softmax only ever holds one (bt, G, bs)
    tile in VMEM (kernel block sizes clamp to the sequence). SSM archs
    have no attention — (0, 0).
    """
    if cfg.family == "ssm":
        return 0, 0
    g = cfg.num_heads // cfg.num_kv_heads
    einsum = cfg.num_kv_heads * g * t * t * 4
    tile = min(bt, t) * g * min(bs, t) * 4
    return einsum, tile


BRAM_BYTES = 2.18 * 2**20            # XC7Z045 (paper §2.1)
VMEM_BYTES = 16 * 2**20              # v5e per-chip VMEM class
HBM_BYTES = 16 * 2**30               # v5e per-chip HBM
CHIPS = 256

PAPER_NETS = {
    "digit (784-1022^3-10)": 2_903_512 - 1022 * 3 - 10,     # weights only
    "phoneme (429-1022^4-61)": 3_638_381 - 1022 * 4 - 61,
}


def bytes_for(n_weights: int, bits: float) -> int:
    if bits == 3:                     # 10 x 3-bit per int32 word
        return (n_weights + 9) // 10 * 4
    return int(n_weights * bits / 8)


def rows():
    out = []
    for name, n in PAPER_NETS.items():
        out.append({
            "net": name, "weights_M": n / 1e6,
            "fp32_MB": bytes_for(n, 32) / 2**20,
            "w8_MB": bytes_for(n, 8) / 2**20,
            "w3_MB": bytes_for(n, 3) / 2**20,
            "fits_bram_w8": bytes_for(n, 8) <= BRAM_BYTES,
            "fits_bram_w3": bytes_for(n, 3) <= BRAM_BYTES,
        })
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = cfg.param_count()
        w3_dev = bytes_for(n, 3) / CHIPS
        score_einsum, score_tile = prefill_score_bytes(cfg)
        out.append({
            "net": arch, "weights_M": n / 1e6,
            "fp32_MB": bytes_for(n, 32) / 2**20,
            "w8_MB": bytes_for(n, 8) / 2**20,
            "w3_MB": bytes_for(n, 3) / 2**20,
            "w3_per_dev_MB": w3_dev / 2**20,
            "fits_vmem_per_dev": w3_dev <= VMEM_BYTES,
            "fits_hbm_per_dev": w3_dev <= HBM_BYTES,
            "kv_bf16_per_tok_B": kv_bytes_per_token(cfg, 16),
            "kv_int8_per_tok_B": kv_bytes_per_token(cfg, 8),
            # 2048-token admission, per layer per sequence: the einsum
            # score tensor the blocked prefill kernel never materializes
            "prefill_score_einsum_MB": score_einsum / 2**20,
            "prefill_score_tile_KB": score_tile / 2**10,
        })
    return out


def main():
    rs = rows()
    print(f"{'net':28s} {'Mw':>8s} {'fp32MB':>8s} {'w8MB':>8s} {'w3MB':>8s} "
          f"{'kv16B/t':>8s} {'kv8B/t':>7s} {'pf32MB':>7s} {'tileKB':>7s}  "
          f"verdict")
    for r in rs:
        if "fits_bram_w3" in r:
            kv = f"{'—':>8s} {'—':>7s} {'—':>7s} {'—':>7s}"
            v = (f"BRAM(2.18MB): w8={'FITS' if r['fits_bram_w8'] else 'NO'} "
                 f"w3={'FITS' if r['fits_bram_w3'] else 'NO'}  <- paper Table 1")
        else:
            kv = (f"{r['kv_bf16_per_tok_B']:>8d} "
                  f"{r['kv_int8_per_tok_B']:>7d} "
                  f"{r['prefill_score_einsum_MB']:>7.0f} "
                  f"{r['prefill_score_tile_KB']:>7.0f}")
            v = (f"w3/dev={r['w3_per_dev_MB']:.0f}MB on 256 chips: "
                 f"VMEM={'FITS' if r['fits_vmem_per_dev'] else 'no'} "
                 f"HBM={'FITS' if r['fits_hbm_per_dev'] else 'NO'}")
        print(f"{r['net']:28s} {r['weights_M']:8.1f} {r['fp32_MB']:8.1f} "
              f"{r['w8_MB']:8.1f} {r['w3_MB']:8.1f} {kv}  {v}")
    return rs


if __name__ == "__main__":
    main()
