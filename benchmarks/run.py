"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  Table 1/2 (resource utilization)  -> memory_footprint
  §4 throughput (70k img/s)         -> throughput
  §4 DRAM bandwidth (630 Gbit/s)    -> bandwidth_math
  §2.1 accuracy (MCR/PER)           -> accuracy
  Table 3 (power)                   -> derived J/inference note in throughput
  roofline/dry-run (this repo's)    -> roofline (reads results/dryrun)
"""
from __future__ import annotations

import traceback


def main() -> None:
    from benchmarks import (accuracy, bandwidth_math, kernels_bench,
                            memory_footprint, throughput)

    print("name,us_per_call,derived")
    for mod in (memory_footprint,):
        try:
            rows = mod.rows()
            for r in rows:
                net = r["net"].replace(" ", "_").replace(",", ";")
                print(f"memory.{net},0.00,w3_MB={r['w3_MB']:.2f};fp32_MB={r['fp32_MB']:.1f}")
        except Exception:
            traceback.print_exc()
    for mod in (throughput, bandwidth_math, accuracy, kernels_bench):
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.2f},{derived}")
        except Exception:
            traceback.print_exc()
    # roofline table (only if dry-run results exist). Single-pod rows only:
    # multi-pod cells carry no reduced-depth lowerings, so their loop costs
    # are body-counted-once (compile/memory proof, not roofline terms).
    try:
        from benchmarks import roofline
        rows = roofline.load_all()
        for r in rows:
            if not r["exact_loops"]:
                continue
            print(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']},"
                  f"{r['step_bound_s'] * 1e6:.1f},"
                  f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
                  f"useful={r['useful_ratio']:.2f}")
    except Exception:
        traceback.print_exc()


if __name__ == "__main__":
    main()
