import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: runs the hypothesis ladders for the three chosen
cells and appends every iteration to results/perf_log.json.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  qwen3-32b:decode_32k     most paper-representative (weight/cache streaming)
  mamba2-2.7b:train_4k     worst roofline fraction
  mixtral-8x22b:train_4k   most collective-bound

Each entry: hypothesis -> change -> before -> after (dominant term) ->
confirmed/refuted. Stops a ladder after 3 consecutive <5% improvements.
"""
import dataclasses

from repro.launch import hillclimb as hc


def run_ladder(cell, steps):
    arch, shape = cell.split(":")
    history = []
    prev_dom = None
    small = 0
    for step in steps:
        knobs = dict(step["knobs"])
        # cfg-level / module-level knobs
        # dequant_bf16 is historical: the unified kernel dispatch
        # (quant_dense.serve_apply) matmuls raw levels in the activation
        # dtype and scales the output, so the fp32 dequantized-weight
        # intermediate that knob used to shrink no longer exists at all.
        knobs.pop("dequant_bf16", False)
        cfg_over = {}
        if knobs.pop("ssm_bf16", False):
            cfg_over["ssm_bf16"] = True
        if knobs.pop("ssm_split_proj", False):
            cfg_over["ssm_split_proj"] = True
        ssm_bf16 = bool(cfg_over)
        if ssm_bf16:
            orig_get = hc.get_config
            hc.get_config = lambda a: dataclasses.replace(orig_get(a),
                                                          **cfg_over)
        try:
            rec, terms = hc.measure(arch, shape, knobs)
        finally:
            if ssm_bf16:
                hc.get_config = orig_get
        dom = terms["step_bound_s"]
        entry = {
            "change": step["change"],
            "hypothesis": step["hypothesis"],
            "knobs": step["knobs"],
            "before": prev_dom if prev_dom is not None else dom,
            "after": dom,
            "terms": {k: terms[k] for k in
                      ("t_compute_s", "t_memory_s", "t_collective_s",
                       "dominant", "useful_ratio", "roofline_fraction")},
        }
        if prev_dom is None:
            entry["verdict"] = "baseline"
        else:
            delta = (dom - prev_dom) / prev_dom
            pred = step.get("predict", "down")
            went_down = delta < -0.001
            entry["verdict"] = (
                "confirmed" if (went_down == (pred == "down")) else "refuted")
            entry["verdict"] += f" ({delta * 100:+.1f}%)"
            if abs(delta) < 0.05:
                small += 1
            else:
                small = 0
        history.append(entry)
        hc.append_log(cell, entry)
        print(f"[{cell}] {step['change']}: bound {dom:.3e}s "
              f"({entry['verdict']})", flush=True)
        if step.get("keep", True) and (prev_dom is None or dom < prev_dom):
            prev_dom = dom
        elif prev_dom is None:
            prev_dom = dom
        if small >= 3:
            print(f"[{cell}] stopping: 3 consecutive <5% changes")
            break
    return history


DECODE_LADDER = [
    dict(change="baseline: paper-faithful w3 containers (in-graph unpack)",
         hypothesis="paper's BRAM image ported naively: 0.4B/wt HBM but the "
                    "jnp unpack chain materializes ~16B/wt of intermediates",
         knobs={}),
    dict(change="float (bf16) weights — GPU-like baseline",
         hypothesis="dropping the unpack chain outweighs 5x bigger weight "
                    "reads at this scale: HLO memory term goes DOWN vs "
                    "containers (the paper's insight NEEDS the fused kernel, "
                    "which is what kernels/qmatvec does on real TPU)",
         knobs={"quant": "float"}, predict="down", keep=False),
    dict(change="w3 levels (int8) instead of containers",
         hypothesis="int8 levels keep 2x-less weight bytes than bf16 without "
                    "the container unpack chain: below the float baseline "
                    "(the fused serve dispatch now matmuls levels in the "
                    "activation dtype — the fp32 dequant intermediate the "
                    "old dequant_bf16 step targeted no longer exists)",
         knobs={"quant": "w3levels"}, predict="down"),
    dict(change="int8 KV cache (+per-token scales)",
         hypothesis="cache reads are ~half the remaining bytes; int8 halves "
                    "them: memory term down ~20-30%",
         knobs={"quant": "w3levels", "kv8": True},
         predict="down"),
]

MAMBA_LADDER = [
    dict(change="baseline: W3A8 QAT train, remat=layer, SSD chunk 256 fp32",
         hypothesis="SSD decay matrices + fp32 internals dominate the "
                    "memory term",
         knobs={}),
    dict(change="SSD einsum operands in bf16",
         hypothesis="the (B,Q,Q,H) decay/score tensors at 4B/elt are the "
                    "biggest SSD traffic: bf16 operands cut the memory term "
                    "~25-40%",
         knobs={"ssm_bf16": True}, predict="down"),
    dict(change="SSD chunk 256 -> 128",
         hypothesis="decay-matrix bytes scale with L*Q: halving Q halves "
                    "that term (state-passing overhead doubles but is N-fold "
                    "smaller)",
         knobs={"ssm_bf16": True, "ssd_chunk": 128}, predict="down"),
    dict(change="remat off (save all activations)",
         hypothesis="layer-remat recomputes the whole SSD forward in bwd: "
                    "remat=none cuts recompute bytes ~30% (memory/dev cost "
                    "visible in memory_analysis)",
         knobs={"ssm_bf16": True, "ssd_chunk": 128, "remat": "none"},
         predict="down"),
    dict(change="SSD chunk 128 -> 64",
         hypothesis="same L*Q scaling: another halving of decay bytes, but "
                    "state-update term (L/Q scans) starts to bite",
         knobs={"ssm_bf16": True, "ssd_chunk": 64, "remat": "none"},
         predict="down"),
]

MAMBA_SPLIT_LADDER = [
    dict(change="shard-aligned split projections (z/x/BC/dt + split convs)",
         hypothesis="the fused in_proj's component boundaries fall inside TP "
                    "shards; GSPMD reshards every component every layer and "
                    "computes B/C with unsharded heads — splitting at shard "
                    "boundaries removes that traffic",
         knobs={"ssm_split_proj": True}, predict="down"),
    dict(change="split projections + SSD bf16 operands",
         hypothesis="with resharding gone, operand width may now matter "
                    "(retest the refuted H-ssd-bf16 on the new baseline)",
         knobs={"ssm_split_proj": True, "ssm_bf16": True}, predict="down"),
]

MIXTRAL_LADDER = [
    dict(change="baseline: W3A8 QAT, FSDP on, remat=layer, micro=1",
         hypothesis="141B fp32 FSDP all-gathers + TP all-reduces dominate "
                    "the collective term",
         knobs={}),
    dict(change="diagnostic: microbatches=4",
         hypothesis="FSDP all-gathers repeat per microbatch: collective "
                    "term should rise ~2-4x, confirming weight-gather "
                    "domination (expected WORSE — diagnostic)",
         knobs={"microbatches": 4}, predict="up", keep=False),
    dict(change="remat off",
         hypothesis="layer-remat re-gathers FSDP weights a third time in "
                    "bwd: remat=none cuts collective term ~30%",
         knobs={"remat": "none"}, predict="down"),
    dict(change="float train (no QAT fake-quant)",
         hypothesis="fake-quant adds elementwise traffic on gathered fp32 "
                    "weights but no collectives: collective term flat, "
                    "memory term down slightly (isolates QAT overhead)",
         knobs={"remat": "none", "quant": "float"}, predict="down",
         keep=False),
]


def main():
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "decode"):
        run_ladder("qwen3-32b:decode_32k", DECODE_LADDER)
    if which in ("all", "mamba"):
        run_ladder("mamba2-2.7b:train_4k", MAMBA_LADDER)
    if which in ("all", "mamba-split", "mamba"):
        run_ladder("mamba2-2.7b:train_4k", MAMBA_SPLIT_LADDER)
    if which in ("all", "mixtral"):
        run_ladder("mixtral-8x22b:train_4k", MIXTRAL_LADDER)


if __name__ == "__main__":
    main()
