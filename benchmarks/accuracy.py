"""Paper §2.1 accuracy table: float vs direct-quant vs retrained W3A8.

Reads results/paper_repro.json (produced by benchmarks.paper_repro — the
long-running full-recipe job); falls back to a fast reduced run if absent.
Paper's claims for context: digit MCR 1.08% (float 1.06%) => gap +0.02pp;
phoneme PER 28.39% (float 27.81%) => gap +0.58pp. The reproduced quantity on
the synthetic stand-in tasks is the small float->W3A8 gap after retraining,
vs the large direct-quantization gap.
"""
from __future__ import annotations

import json
import os

RESULTS = "results/paper_repro.json"
PAPER = {"digit": {"float": 1.06, "w3a8": 1.08},
         "phoneme": {"float": 27.81, "w3a8": 28.39}}


def run(path=RESULTS):
    if not os.path.exists(path):
        from benchmarks.paper_repro import main as repro_main
        repro_main(path, fast=True)
    data = json.load(open(path))
    rows = []
    for task, m in data.items():
        p = PAPER[task]
        rows.append((f"accuracy.{task}", 0.0,
                     f"float={m['float_mcr']:.2f};direct={m['direct_quant_mcr']:.2f};"
                     f"w3a8={m['w3a8_mcr']:.2f};gap_pp={m['gap_pp']:.2f};"
                     f"paper_gap_pp={p['w3a8'] - p['float']:.2f};"
                     f"compression={m['weight_bytes_float'] / m['weight_bytes_packed']:.1f}x"))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
