"""Serving throughput vs slot count: the paper's weight-streaming
amortization curve, measured — under MIXED-LENGTH traffic.

The paper's Fig. 4 dataflow streams quantized weights once per step
regardless of batch size, so tokens/sec should rise near-linearly with the
number of co-resident decode slots until compute saturates. This benchmark
drives the batched continuous-batching engine over a fixed mixed-length
request set (prompts spanning both admission buckets, the heavy-traffic
shape the bucketed prefill path exists for) at several slot counts and
reports tokens/sec per weight form (float ``w``, int8 levels ``q``, packed
3-bit containers ``qp`` — the deployed form, where the per-tick cost is
dominated by the batch-independent container unpack and the amortization is
strongest), plus admission throughput: batched ``prefills`` issued and
prompt tokens/sec (``ptok/s``) absorbed through them.

Per-config timing is split into prefill vs decode seconds (engine profile
timers): non-monotonic tok/s points are usually an admission effect — more
slots means fewer, larger batched prefills — and the split pins down which
phase moved. The profile wrapper blocks on each jitted call, trading the
engine's async-drain overlap for phase attribution; on CPU (effectively
synchronous execution) the measured overhead is nil, but pass
``--no-profile`` to time the pure async path (no split in the artifact).
``--matmul-mode`` selects the quantized-matmul dispatch
(auto/kernel/dequant; kernel is interpret-mode off-TPU), ``--attn-mode``
the decode-attention dispatch (auto/kernel/ref — the fused Pallas
``attn_decode`` kernel vs the einsum path), and ``--kv8`` serves from an
int8 KV cache; every row reports the shared-cache bytes per slot, which
kv8 halves (twice the slots per fixed cache budget).

``--mix long`` swaps the short-prompt traffic for 1k–4k-token prompts
(admission buckets 1024/2048/4096), the regime where prefill attention
dominates admission cost: the einsum path materializes an O(T^2) fp32 score
tensor per sequence while the blocked Pallas kernel (``--attn-mode
kernel``) keeps one (bt, G, bs) tile in VMEM — the ``pfill_s`` column is
the number that moves. The long mix defaults to fewer slots/requests, one
repeat and a 256-token ``--attn-chunk`` (caps the ref-mode chunked-prefill
working set; the engine threads it through to ``chunked_attention``).

``--spec-k K`` adds the speculative-serving axis: a packed-3-bit drafter
derived from the same checkpoint (``api.draft_of``; ``--draft-depth`` for
the half-depth variant) proposes K tokens per tick and the swept form
verifies them in one multi-token pass. The ``acc/tick`` column reports
tokens committed per slot-tick (exactly 1.0 without speculation — the
tokens-per-tick multiplier is the whole point), plus the drain-synced
``spec_accept_rate`` in the artifact; ``--check`` then gates on
accepted-tokens-per-tick > 1 in every swept cell instead of the qp
monotonicity curve.

``--mix crash`` exercises the durability layer instead of the
amortization curve: each cell runs with periodic snapshots + a write-ahead
journal, injects a process kill mid-run (``FaultPlan.crash_at_tick``),
then recovers a FRESH engine — restore the latest snapshot, replay the
journal tail — and finishes the workload. Reported per cell: snapshot
step restored, journal events replayed, requests resubmitted, restore
seconds, and the zero-loss verdict (pre-crash drains + recovered outputs
token-identical to an uncrashed reference at T=0). ``--check`` gates on
zero accepted-token loss AND an actual snapshot restore in every cell;
the artifact goes to ``BENCH_serving_durability.json`` by default.

Results are also written as a JSON artifact (default ``BENCH_serving.json``)
so CI can archive the perf trajectory.

    PYTHONPATH=src python benchmarks/serving_bench.py
    PYTHONPATH=src python benchmarks/serving_bench.py --check   # CI gate

Runs on CPU in a couple of minutes at the default reduced size.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import quant_dense
from repro.core.precision import FLOAT, W3A8
from repro.models import get_model
from repro.serving.engine import ServingEngine

MIXES = {
    # short prompts cycling over both small admission buckets (<=8, 9..16)
    "mixed": [3, 8, 5, 12, 4, 16, 7, 9],
    # 1k-4k prompts (buckets 1024/2048/4096): admission time is dominated
    # by prefill attention, the regime the blocked kernel exists for
    "long": [1024, 2048, 1536, 4096],
    # overload: same short prompts, but arrival-paced at ~2x the engine's
    # slot-tick service capacity under bounded admission + mixed deadlines
    # + preemption — measures shed/deadline-miss/latency, not amortization
    "overload": [3, 8, 5, 12, 4, 16, 7, 9],
    # crash: kill the engine mid-run, recover a FRESH engine from the
    # latest snapshot + journal tail — measures restore/replay cost and
    # verifies zero accepted-token loss (recovered == uncrashed at T=0)
    "crash": [3, 8, 5, 12, 4, 16, 7, 9],
}
# per-mix defaults for the knobs whose sensible values depend on prompt
# scale: (slots, requests, max_new, repeats, attn_chunk)
MIX_DEFAULTS = {
    "mixed": ("1,4,8,16", 16, 24, 3, 1024),
    "long": ("1,2", 4, 8, 1, 256),
    "overload": ("2,4", 24, 12, 1, 1024),
    "crash": ("2,4", 12, 12, 1, 1024),
}


def _prompts(requests: int, lengths):
    return [[(i * 7 + j) % 50 + 1
             for j in range(lengths[i % len(lengths)])]
            for i in range(requests)]


def _engine(params, cfg, policy, slots, max_prompt, max_new,
            matmul_mode="auto", attn_mode="auto", kv_bits=None, spec_k=0,
            draft=None, profile=True, attn_chunk=1024):
    return ServingEngine(params, cfg, policy=policy, slots=slots,
                         max_len=max_prompt + max_new + 1 + spec_k,
                         dtype=jnp.float32, matmul_mode=matmul_mode,
                         attn_mode=attn_mode, kv_bits=kv_bits,
                         spec_k=spec_k,
                         draft_params=draft[1] if draft else None,
                         draft_cfg=draft[0] if draft else None,
                         profile=profile, attn_chunk=attn_chunk)


def _cache_bytes_per_slot(eng: ServingEngine) -> int:
    """Shared-cache bytes divided by slots — the number kv_bits=8 halves
    (KV entries go bf16/f32 -> int8 + one fp32 scale per token)."""
    total = sum(x.size * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(eng.cache))
    return total // eng.slots


def bench_form(params, cfg, policy, *, slots: int, requests: int,
               max_new: int, lengths, repeats: int = 3,
               matmul_mode: str = "auto", attn_mode: str = "auto",
               kv_bits=None, spec_k: int = 0, draft=None,
               profile: bool = True, attn_chunk: int = 1024) -> dict:
    # warmup on the SAME engine instance that gets timed: the jitted
    # prefill/tick closures are per-engine, so a throwaway warmup engine
    # would leave the timed run paying compile time. One prompt per
    # admission bucket the mix touches compiles every batched-prefill entry.
    eng = _engine(params, cfg, policy, slots, max(lengths), max_new,
                  matmul_mode, attn_mode, kv_bits, spec_k, draft, profile,
                  attn_chunk)
    for bucket in sorted({eng._bucket_len(n) for n in lengths}):
        eng.submit([1] * bucket, max_new=max_new)
    eng.run_all()

    # best-of-N: CPU wall-clock noise (scheduler, allocator) easily exceeds
    # the 4->8-slot amortization step on sub-second runs; min time is the
    # standard denoiser
    prompts = _prompts(requests, lengths)
    ptoks = sum(len(p) for p in prompts)
    best = None
    for _ in range(repeats):
        ticks0, prefills0 = eng.decode_calls, eng.prefill_calls
        psecs0, dsecs0 = eng.prefill_secs, eng.decode_secs
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run_all()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in done)
        # the prefill/decode split makes per-phase regressions visible: a
        # tok/s dip can hide admission cost (more slots => fewer, bigger
        # batched prefills) behind decode amortization, and vice versa
        ticks = eng.decode_calls - ticks0
        # per-slot speculative win: decode-emitted tokens per request tick
        # (the admission sample rides prefill, so it is excluded). Exactly
        # 1.0 without speculation; 1 + mean accepted drafts with it.
        slot_ticks = sum(r.ticks for r in done)
        dec_toks = sum(len(r.out) - 1 for r in done)
        r = {"slots": slots, "tokens": toks, "secs": dt,
             "tok_per_sec": toks / dt, "ticks": ticks,
             "prefills": eng.prefill_calls - prefills0,
             "prompt_tokens": ptoks, "prompt_tok_per_sec": ptoks / dt,
             "prefill_secs": eng.prefill_secs - psecs0,
             "decode_secs": eng.decode_secs - dsecs0,
             "attn_mode": attn_mode, "kv_bits": kv_bits,
             "spec_k": spec_k,
             "accepted_tok_per_tick": dec_toks / max(slot_ticks, 1),
             "spec_accept_rate": eng.spec_accept_rate,
             "cache_bytes_per_slot": _cache_bytes_per_slot(eng)}
        if best is None or r["tok_per_sec"] > best["tok_per_sec"]:
            best = r
    return best


def bench_overload(params, cfg, policy, *, slots: int, requests: int,
                   max_new: int, lengths, matmul_mode: str = "auto",
                   attn_mode: str = "auto", kv_bits=None,
                   attn_chunk: int = 1024, max_ticks: int = 4096) -> dict:
    """Overload scenario: requests arrive in waves of ``2 * slots`` every 4
    ticks — roughly 2x the slot-tick service rate, so the bounded queue
    (``queue_limit = 2 * slots``, reject policy) must shed and the
    fair-share preemption/deadline machinery is exercised, not idle.
    Deadlines cycle none / loose (4 * max_new) / tight (max_new // 2), so a
    fraction of requests CANNOT finish in time by construction. Reports
    shed-rate, deadline-miss-rate, preemption count and submit->finish
    latency percentiles; ``deadlocked`` records whether the watchdog fired
    (the --check gate requires it never does)."""
    from repro.serving.resilience import WatchdogExpired
    eng = ServingEngine(params, cfg, policy=policy, slots=slots,
                        max_len=max(lengths) + max_new + 1,
                        dtype=jnp.float32, matmul_mode=matmul_mode,
                        attn_mode=attn_mode, kv_bits=kv_bits,
                        attn_chunk=attn_chunk,
                        queue_limit=2 * slots, shed_policy="reject",
                        preempt_after=max(2, max_new // 4),
                        max_ticks=max_ticks)
    prompts = _prompts(requests, lengths)
    deadlines = [None, 4 * max_new, max(1, max_new // 2)]
    outcomes, done = [], []
    deadlocked = False
    t0 = time.perf_counter()
    wave = 2 * slots
    for i in range(0, len(prompts), wave):
        for j, p in enumerate(prompts[i:i + wave]):
            outcomes.append(eng.submit(
                p, max_new=max_new,
                deadline_ticks=deadlines[(i + j) % len(deadlines)]))
        for _ in range(4):                 # serve between arrival waves
            eng.step()
        done.extend(eng.drain())
    try:
        done.extend(eng.run_all())
    except WatchdogExpired:
        deadlocked = True
        done.extend(eng.drain())
    dt = time.perf_counter() - t0
    accepted = sum(1 for o in outcomes if o.accepted)
    lats = sorted(r.finish_time - r.submit_time for r in done
                  if r.submit_time and r.finish_time)
    pct = (lambda q: lats[min(len(lats) - 1, int(q * len(lats)))]) if lats \
        else (lambda q: 0.0)
    toks = sum(len(r.out) for r in done)
    return {"slots": slots, "submitted": len(outcomes), "accepted": accepted,
            "completed_ok": sum(1 for r in done if r.status == "ok"),
            "shed_rate": eng.shed_count / max(len(outcomes), 1),
            "deadline_miss_rate": eng.deadline_miss_count / max(accepted, 1),
            "preemptions": eng.preempt_count,
            "poisoned": eng.poisoned_count,
            "queue_peak": eng.queue_peak,
            "latency_p50_s": pct(0.50), "latency_p99_s": pct(0.99),
            "tokens": toks, "secs": dt, "tok_per_sec": toks / dt,
            "ticks": eng.decode_calls, "deadlocked": deadlocked,
            "attn_mode": attn_mode, "kv_bits": kv_bits}


def bench_crash(params, cfg, policy, *, slots: int, requests: int,
                max_new: int, lengths, matmul_mode: str = "auto",
                attn_mode: str = "auto", kv_bits=None,
                attn_chunk: int = 1024, snapshot_every: int = 8,
                max_ticks: int = 4096) -> dict:
    """Kill-and-recover scenario: run with periodic snapshots + a
    write-ahead journal, inject a process kill mid-run, then recover a
    FRESH engine (restore latest snapshot, replay the journal tail) and
    finish the workload. Reports restore/replay cost (``restore_secs``,
    ``replayed_events``, ``resubmitted``) and verifies ZERO accepted-token
    loss: the union of pre-crash drains and the recovered run must be
    token-identical to an uncrashed reference at T=0 (``lost_requests``
    and ``mismatched_requests`` must both be 0 — the --check gate)."""
    import os
    import shutil
    import tempfile

    from repro.serving.resilience import FaultPlan, InjectedCrash

    def mk(**kw):
        return ServingEngine(params, cfg, policy=policy, slots=slots,
                             max_len=max(lengths) + max_new + 1,
                             dtype=jnp.float32, matmul_mode=matmul_mode,
                             attn_mode=attn_mode, kv_bits=kv_bits,
                             attn_chunk=attn_chunk, **kw)

    prompts = _prompts(requests, lengths)
    ref_eng = mk()
    for p in prompts:
        ref_eng.submit(p, max_new=max_new)
    ref = {r.uid: tuple(r.out) for r in ref_eng.run_all(max_ticks=max_ticks)}

    tmp = tempfile.mkdtemp(prefix="crashbench_")
    snaps, jpath = os.path.join(tmp, "snaps"), os.path.join(tmp, "wal.jsonl")
    # kill roughly mid-workload: past at least one periodic snapshot, well
    # before the last request finishes
    crash_tick = max(snapshot_every + 1, (requests * max_new) // (2 * slots))
    eng = mk(snapshot_dir=snaps, snapshot_every=snapshot_every,
             journal=jpath, fault_plan=FaultPlan(crash_at_tick=crash_tick))
    for p in prompts:
        eng.submit(p, max_new=max_new)
    delivered: dict = {}
    t0 = time.perf_counter()
    ticks = 0
    try:
        while eng.queue or eng._occupied():
            eng.step()
            ticks += 1
            delivered.update({r.uid: tuple(r.out) for r in eng.drain()})
            if ticks > max_ticks:
                break
    except InjectedCrash:
        pass
    uptime = time.perf_counter() - t0

    t1 = time.perf_counter()
    fresh = mk(snapshot_dir=snaps, journal=jpath)
    stats = fresh.recover()
    restore_secs = time.perf_counter() - t1
    t2 = time.perf_counter()
    recovered = {r.uid: tuple(r.out)
                 for r in fresh.run_all(max_ticks=max_ticks)}
    finish_secs = time.perf_counter() - t2
    shutil.rmtree(tmp, ignore_errors=True)

    merged = {**delivered, **recovered}
    lost = [u for u in ref if u not in merged]
    mismatched = [u for u in ref if u in merged and merged[u] != ref[u]]
    toks = sum(len(v) for v in recovered.values())
    return {"slots": slots, "requests": requests,
            "crash_tick": crash_tick, "uptime_secs": uptime,
            "snapshot_every": snapshot_every,
            "restored_step": stats["restored_step"],
            "replayed_events": stats["replayed_events"],
            "resubmitted": stats["resubmitted"],
            "restore_secs": restore_secs, "finish_secs": finish_secs,
            "recovered_tokens": toks,
            "delivered_pre_crash": len(delivered),
            "lost_requests": len(lost),
            "mismatched_requests": len(mismatched),
            "zero_loss": not lost and not mismatched,
            "attn_mode": attn_mode, "kv_bits": kv_bits}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--mix", default="mixed", choices=sorted(MIXES),
                    help="request traffic: 'mixed' short prompts over the "
                         "small admission buckets, 'long' 1k-4k prompts "
                         "where prefill attention dominates admission, "
                         "'overload' 2x-capacity arrivals under bounded "
                         "admission, 'crash' kill-and-recover durability")
    ap.add_argument("--slots", default=None,
                    help="comma-separated slot counts to sweep "
                         "(default per mix: mixed=1,4,8,16 long=1,2)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--forms", default="qp,q,w")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed repetitions per config; best run reported "
                         "(default per mix: mixed=3 long=1)")
    ap.add_argument("--attn-chunk", type=int, default=None,
                    help="ref-mode chunked-prefill query-chunk length "
                         "(bounds the einsum score working set; default "
                         "per mix: mixed=1024 long=256)")
    ap.add_argument("--matmul-mode", default="auto",
                    choices=["auto", "kernel", "dequant"],
                    help="quantized-matmul dispatch for the q/qp forms "
                         "(kernel = Pallas, interpret mode off-TPU — slow "
                         "on CPU, for kernel-path measurement only)")
    ap.add_argument("--attn-mode", default="auto",
                    choices=["auto", "kernel", "ref"],
                    help="decode-attention dispatch (kernel = fused Pallas "
                         "attn_decode, interpret mode off-TPU)")
    ap.add_argument("--kv8", action="store_true",
                    help="serve from an int8 KV cache: halves the "
                         "cache-bytes-per-slot column")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding axis: a packed-3-bit drafter "
                         "(api.draft_of of the same checkpoint) proposes K "
                         "tokens per tick; adds the acc/tick column (tokens "
                         "committed per slot-tick, 1.0 without spec)")
    ap.add_argument("--draft-depth", type=float, default=1.0,
                    help="drafter depth fraction for --spec-k (0.5 = the "
                         "half-depth draft variant)")
    ap.add_argument("--no-profile", action="store_true",
                    help="disable the per-phase timers (they block on each "
                         "jitted call): times the pure async engine, at the "
                         "cost of the prefill/decode split in the artifact")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless qp tokens/sec is monotonically "
                         "increasing from 1 to 8 slots")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="JSON artifact path ('' disables)")
    args = ap.parse_args()

    lengths = MIXES[args.mix]
    d_slots, d_requests, d_max_new, d_repeats, d_chunk = MIX_DEFAULTS[args.mix]
    if args.slots is None:
        args.slots = d_slots
    if args.requests is None:
        args.requests = d_requests
    if args.max_new is None:
        args.max_new = d_max_new
    if args.repeats is None:
        args.repeats = d_repeats
    if args.attn_chunk is None:
        args.attn_chunk = d_chunk

    cfg = reduced(get_config(args.arch), layers=args.layers,
                  d_model=args.d_model, vocab=args.vocab)
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg)
    W3 = dataclasses.replace(W3A8, act_bits=None)
    form_params = {
        "w": (params, FLOAT),
        "q": (quant_dense.export_levels(params, W3), W3),
        "qp": (quant_dense.export_container(params, W3), W3),
    }
    # the drafter comes from the SAME checkpoint (self-speculation): every
    # form is verified by its own weights with the qp slice drafting
    draft = None
    if args.spec_k:
        from repro.models import api as model_api
        draft = model_api.draft_of(cfg, params, policy=W3,
                                   depth_fraction=args.draft_depth)
    slot_counts = [int(s) for s in args.slots.split(",")]

    results: dict = {}
    print(f"{cfg.name} reduced(L={args.layers}, d={args.d_model}, "
          f"V={args.vocab}), {args.requests} {args.mix}-mix requests "
          f"(prompt lens {lengths}) x {args.max_new} tokens")
    kv_bits = 8 if args.kv8 else None

    if args.mix == "overload":
        print(f"{'form':>4} {'slots':>5} {'subm':>5} {'acc':>4} "
              f"{'shed%':>6} {'dlmiss%':>7} {'preempt':>7} {'qpeak':>5} "
              f"{'p50_s':>7} {'p99_s':>7} {'tok/s':>8} {'wedged':>6}")
        for form in args.forms.split(","):
            p, pol = form_params[form]
            results[form] = []
            for slots in slot_counts:
                r = bench_overload(p, cfg, pol, slots=slots,
                                   requests=args.requests,
                                   max_new=args.max_new, lengths=lengths,
                                   matmul_mode=args.matmul_mode,
                                   attn_mode=args.attn_mode, kv_bits=kv_bits,
                                   attn_chunk=args.attn_chunk)
                results[form].append(r)
                print(f"{form:>4} {r['slots']:>5} {r['submitted']:>5} "
                      f"{r['accepted']:>4} {100 * r['shed_rate']:>6.1f} "
                      f"{100 * r['deadline_miss_rate']:>7.1f} "
                      f"{r['preemptions']:>7} {r['queue_peak']:>5} "
                      f"{r['latency_p50_s']:>7.3f} {r['latency_p99_s']:>7.3f} "
                      f"{r['tok_per_sec']:>8.1f} "
                      f"{str(r['deadlocked']):>6}")
        if args.out:
            artifact = {
                "bench": "serving", "arch": cfg.name,
                "reduced": {"layers": args.layers, "d_model": args.d_model,
                            "vocab": args.vocab},
                "requests": args.requests, "max_new": args.max_new,
                "mix": args.mix, "mix_lengths": lengths,
                "matmul_mode": args.matmul_mode,
                "attn_mode": args.attn_mode, "kv_bits": kv_bits,
                "results": results,
            }
            with open(args.out, "w") as f:
                json.dump(artifact, f, indent=2)
            print(f"wrote {args.out}")
        cells = [r for rs in results.values() for r in rs]
        # overload gate: the engine must never deadlock (every run drains
        # to completion under the watchdog) and bounded admission must not
        # degenerate into shedding EVERYTHING (some work always completes)
        ok = (bool(cells)
              and all(not r["deadlocked"] for r in cells)
              and all(r["shed_rate"] < 1.0 for r in cells)
              and all(r["completed_ok"] > 0 for r in cells))
        print(f"overload gate (no deadlock, shed-rate < 1.0, some requests "
              f"complete) over {len(cells)} cells: {ok}")
        if args.check and not ok:
            raise SystemExit(1)
        return

    if args.mix == "crash":
        out = args.out
        if out == "BENCH_serving.json":          # mix-specific default
            out = "BENCH_serving_durability.json"
        print(f"{'form':>4} {'slots':>5} {'ctick':>5} {'snap':>5} "
              f"{'replay':>6} {'resub':>5} {'restore_s':>9} {'finish_s':>8} "
              f"{'lost':>4} {'mism':>4} {'0loss':>5}")
        for form in args.forms.split(","):
            p, pol = form_params[form]
            results[form] = []
            for slots in slot_counts:
                r = bench_crash(p, cfg, pol, slots=slots,
                                requests=args.requests,
                                max_new=args.max_new, lengths=lengths,
                                matmul_mode=args.matmul_mode,
                                attn_mode=args.attn_mode, kv_bits=kv_bits,
                                attn_chunk=args.attn_chunk)
                results[form].append(r)
                print(f"{form:>4} {r['slots']:>5} {r['crash_tick']:>5} "
                      f"{str(r['restored_step']):>5} "
                      f"{r['replayed_events']:>6} {r['resubmitted']:>5} "
                      f"{r['restore_secs']:>9.3f} {r['finish_secs']:>8.1f} "
                      f"{r['lost_requests']:>4} {r['mismatched_requests']:>4} "
                      f"{str(r['zero_loss']):>5}")
        if out:
            artifact = {
                "bench": "serving_durability", "arch": cfg.name,
                "reduced": {"layers": args.layers, "d_model": args.d_model,
                            "vocab": args.vocab},
                "requests": args.requests, "max_new": args.max_new,
                "mix": args.mix, "mix_lengths": lengths,
                "matmul_mode": args.matmul_mode,
                "attn_mode": args.attn_mode, "kv_bits": kv_bits,
                "results": results,
            }
            with open(out, "w") as f:
                json.dump(artifact, f, indent=2)
            print(f"wrote {out}")
        cells = [r for rs in results.values() for r in rs]
        # durability gate: every cell must recover with ZERO accepted-token
        # loss (recovered+pre-crash drains token-identical to an uncrashed
        # run at T=0) AND must actually have restored from a snapshot
        ok = (bool(cells)
              and all(r["zero_loss"] for r in cells)
              and all(r["restored_step"] is not None for r in cells))
        print(f"durability gate (zero accepted-token loss, snapshot "
              f"restored) over {len(cells)} cells: {ok}")
        if args.check and not ok:
            raise SystemExit(1)
        return

    print(f"{'form':>4} {'slots':>5} {'tokens':>7} {'ticks':>6} "
          f"{'prefills':>8} {'secs':>7} {'pfill_s':>7} {'dec_s':>7} "
          f"{'tok/s':>8} {'ptok/s':>8} {'acc/tick':>8} {'KB/slot':>8}")
    for form in args.forms.split(","):
        p, pol = form_params[form]
        results[form] = []
        for slots in slot_counts:
            r = bench_form(p, cfg, pol, slots=slots, requests=args.requests,
                           max_new=args.max_new, lengths=lengths,
                           repeats=args.repeats,
                           matmul_mode=args.matmul_mode,
                           attn_mode=args.attn_mode, kv_bits=kv_bits,
                           spec_k=args.spec_k, draft=draft,
                           profile=not args.no_profile,
                           attn_chunk=args.attn_chunk)
            results[form].append(r)
            print(f"{form:>4} {r['slots']:>5} {r['tokens']:>7} "
                  f"{r['ticks']:>6} {r['prefills']:>8} {r['secs']:>7.2f} "
                  f"{r['prefill_secs']:>7.2f} {r['decode_secs']:>7.2f} "
                  f"{r['tok_per_sec']:>8.1f} {r['prompt_tok_per_sec']:>8.1f} "
                  f"{r['accepted_tok_per_tick']:>8.2f} "
                  f"{r['cache_bytes_per_slot'] / 1024:>8.1f}")

    if args.out:
        artifact = {
            "bench": "serving", "arch": cfg.name,
            "reduced": {"layers": args.layers, "d_model": args.d_model,
                        "vocab": args.vocab},
            "requests": args.requests, "max_new": args.max_new,
            "mix": args.mix, "mix_lengths": lengths,
            "repeats": args.repeats, "attn_chunk": args.attn_chunk,
            "matmul_mode": args.matmul_mode,
            "attn_mode": args.attn_mode, "kv_bits": kv_bits,
            "spec_k": args.spec_k, "draft_depth": args.draft_depth,
            # with --no-profile the per-phase timers never run, so the
            # prefill_secs/decode_secs fields are 0.0-by-absence — this
            # flag lets artifact consumers tell that apart from a
            # measured-zero phase
            "profile": not args.no_profile,
            "results": results,
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.out}")

    if args.spec_k:
        # speculative gate: every swept cell must commit MORE than one
        # token per slot-tick — i.e. the drafter earns its keep (the
        # tokens-per-tick multiplier the subsystem exists for)
        cells = [(f, r["slots"], r["accepted_tok_per_tick"])
                 for f, rs in results.items() for r in rs]
        ok = all(a > 1.0 for _, _, a in cells)
        print(f"spec_k={args.spec_k} accepted-tokens-per-tick > 1 in all "
              f"{len(cells)} cells: {ok} "
              f"(min {min(a for _, _, a in cells):.2f})")
        if args.check and not (cells and ok):
            raise SystemExit(1)
        return

    pts = [(r["slots"], r["tok_per_sec"]) for r in results.get("qp", ())
           if r["slots"] in (1, 4, 8)]
    ok = all(a[1] < b[1] for a, b in zip(pts, pts[1:]))
    if pts:
        print(f"qp amortization monotonic over slots "
              f"{'/'.join(str(s) for s, _ in pts)}: {ok} "
              f"({' -> '.join(f'{x:.1f}' for _, x in pts)} tok/s)")
    if args.check:
        # the gate must never pass vacuously: it needs the full qp 1/4/8 curve
        if {s for s, _ in pts} != {1, 4, 8}:
            raise SystemExit("--check needs form qp and slots 1,4,8 in the "
                             "sweep (got qp points for "
                             f"{sorted(s for s, _ in pts)})")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
