"""Paper §4 bandwidth argument, reproduced and retargeted.

Paper: at 70k img/s with 3M 3-bit weights re-read per image, DRAM would need
3 x 3M x 70k = 630 Gbit/s vs the ZC706's 102.4 Gbit/s — hence on-chip-only.

TPU analogue: decode of qwen2-1.5b at batch 128 — per token every weight is
read once; bf16 weights need 2B/wt of HBM, W3-packed 0.4B/wt: the same 5x
argument that converts a bandwidth-bound workload toward compute-bound.
"""
from __future__ import annotations

from repro.configs import get_config

V5E_HBM = 819e9  # B/s

# per-weight HBM traffic of each serve form, bytes/weight (the number the
# README "serve forms & kernel dispatch" table cites):
#   w   bf16 float weights                      2.0  (GPU-like baseline)
#   q   form A, int8 levels (Pallas qmatmul)    1.0
#   qp  form B, 3-bit containers, 10 wt/int32
#       (Pallas qmatvec — the paper's BRAM image) 0.4  (= 3.2 bits)
SERVE_FORM_BYTES = {"w": 2.0, "q": 1.0, "qp": 0.4}

# decode's OTHER HBM stream: per generated token, attention re-reads every
# valid cache position — context_len * kv_bytes_per_token per step. The
# engine's kv_bits=8 form (int8 entries + 2 fp32 per-token scales per cache
# layer, read by the fused attn_decode kernel) halves it vs bf16.
KV_DECODE_CONTEXT = 4096


def serve_form_table(arch: str = "qwen2-1.5b"):
    """Decode bandwidth bound per serve form: one full weight read per
    token, tok/s = HBM_bytes_per_s / (params * bytes_per_weight)."""
    n = get_config(arch).param_count()
    return {form: {"bytes_per_weight": bpw,
                   "tok_per_s_per_chip": V5E_HBM / (n * bpw)}
            for form, bpw in SERVE_FORM_BYTES.items()}


def run():
    rows = []
    # --- the paper's own arithmetic -------------------------------------------
    weights = 3.0e6
    imgs = 70_000
    dram_need_gbit = 3 * weights * imgs / 1e9
    rows.append(("paper.dram_need_gbit_s", 0.0,
                 f"computed={dram_need_gbit:.0f};paper_claims=630;board=102.4"))

    # --- TPU decode analogue ---------------------------------------------------
    cfg = get_config("qwen2-1.5b")
    n = cfg.param_count()
    for name, bytes_per_w in (("bf16", 2.0), ("w8", 1.0), ("w3_packed", 0.4)):
        toks_per_s = V5E_HBM / (n * bytes_per_w)     # single chip, batch>=1
        rows.append((f"decode.qwen2-1.5b.{name}", 1e6 / toks_per_s,
                     f"tokens_per_s_per_chip={toks_per_s:.0f}"))

    # --- per-serve-form traffic table (the engine's w/q/qp axis) --------------
    for form, t in serve_form_table(cfg.name).items():
        rows.append((f"serve_form.{cfg.name}.{form}",
                     1e6 / t["tok_per_s_per_chip"],
                     f"bytes_per_weight={t['bytes_per_weight']};"
                     f"tokens_per_s_per_chip={t['tok_per_s_per_chip']:.0f}"))

    # --- KV-cache traffic (the engine's kv_bits axis) --------------------------
    try:                       # package context (benchmarks/run.py) ...
        from benchmarks.memory_footprint import kv_bytes_per_token
    except ImportError:        # ... or run directly as a script
        from memory_footprint import kv_bytes_per_token
    for name, bits in (("bf16", 16), ("int8", 8)):
        per_tok = kv_bytes_per_token(cfg, bits)
        per_step = per_tok * KV_DECODE_CONTEXT           # read per decode step
        rows.append((f"kv_cache.{cfg.name}.{name}",
                     per_step / V5E_HBM * 1e6,           # us of HBM per step
                     f"bytes_per_token={per_tok};"
                     f"read_per_step_at_{KV_DECODE_CONTEXT}ctx_MB="
                     f"{per_step / 2**20:.1f}"))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
